"""Encoder-decoder backbone (seamless-m4t-large-v2 text/audio backbone).

Per the assignment spec the modality frontend is a STUB: the encoder
consumes precomputed frame embeddings ``(B, L_src, d_model)`` (what the
conformer audio frontend would emit); ``input_specs`` provides them as
ShapeDtypeStructs for the dry-run and the data pipeline synthesizes them
for smoke tests.

Structure (standard transformer enc-dec, pre-norm):
  * encoder: n_encoder_layers × [bidirectional self-attn + MLP], scanned.
  * decoder: n_layers × [causal self-attn + cross-attn(enc_out) + MLP],
    scanned.

Serving: ``encdec_prefill`` encodes the source once, *precomputes the
cross-attention K/V per decoder layer* (they are decode-invariant) and
prefills the decoder self-attention cache; ``encdec_decode_step`` then
touches only cached tensors.  Sparsity applies to every projection via
``apply_linear``.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import (_sdpa, attention, init_attention)
from repro.models.config import ModelConfig

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_enc_layer(rng: Array, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(rng, 2)
    return {
        "ln_attn": L.init_rmsnorm(cfg.d_model),
        "attn": init_attention(ks[0], cfg, dtype=dtype),
        "ln_mlp": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                          gated=cfg.mlp_gated, dtype=dtype),
    }


def _init_dec_layer(rng: Array, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "ln_self": L.init_rmsnorm(cfg.d_model),
        "self_attn": init_attention(ks[0], cfg, dtype=dtype),
        "ln_cross": L.init_rmsnorm(cfg.d_model),
        "cross_attn": init_attention(ks[1], cfg, dtype=dtype),
        "ln_mlp": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                          gated=cfg.mlp_gated, dtype=dtype),
    }


def init_encdec(rng: Array, cfg: ModelConfig) -> Params:
    dtype = L._dtype(cfg.dtype)
    k_embed, k_enc, k_dec = jax.random.split(rng, 3)
    enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": L.init_embedding(k_embed, cfg.vocab_padded, cfg.d_model,
                                  dtype),
        "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            enc_keys),
        "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
            dec_keys),
        "ln_enc_final": L.init_rmsnorm(cfg.d_model),
        "ln_dec_final": L.init_rmsnorm(cfg.d_model),
    }


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int,
                      src_len: int, dtype=jnp.bfloat16,
                      page_size: int = 0, num_pages: int = 0) -> Params:
    """Self KV (n_layers, B, max_len, Hk, D) + decode-invariant cross KV
    (n_layers, B, src_len, Hk, D), filled by ``encdec_prefill``.

    ``page_size > 0`` pages the decoder *self* cache (the only part that
    grows with decode length); cross K/V is written once per request at a
    fixed per-slot ``src_len``, so paging it buys nothing.
    """
    from repro.models.attention import init_paged_kv_cache
    if page_size:
        self_cache = init_paged_kv_cache(cfg, batch, max_len, page_size,
                                         num_pages, dtype=dtype)
    else:
        self_shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                      cfg.head_dim)
        self_cache = {"k": jnp.zeros(self_shape, dtype),
                      "v": jnp.zeros(self_shape, dtype)}
    cross_shape = (cfg.n_layers, batch, src_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "self": self_cache,
        "cross": {"k": jnp.zeros(cross_shape, dtype),
                  "v": jnp.zeros(cross_shape, dtype)},
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params: Params, cfg: ModelConfig, src: Array) -> Array:
    """``src`` (B, L_src, d_model) frame embeddings → encoder output."""
    B, Ls, _ = src.shape
    positions = jnp.broadcast_to(jnp.arange(Ls), (B, Ls))

    def body(x, p_layer):
        h = L.rmsnorm(p_layer["ln_attn"], x, cfg.norm_eps)
        out, _ = attention(p_layer["attn"], cfg, h, positions, causal=False,
                           sparsity=cfg.attn_sparsity)
        x = x + out
        h = L.rmsnorm(p_layer["ln_mlp"], x, cfg.norm_eps)
        return x + L.mlp(p_layer["mlp"], h, gated=cfg.mlp_gated,
                         sparsity=cfg.mlp_sparsity), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, src.astype(L._dtype(cfg.dtype)),
                        params["encoder"])
    return L.rmsnorm(params["ln_enc_final"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder (training / teacher-forcing path: recomputes cross K/V in-layer)
# ---------------------------------------------------------------------------

def decode_hidden(params: Params, cfg: ModelConfig, tokens: Array,
                  enc_out: Array) -> Array:
    """Teacher-forcing decoder trunk → final (normed) hidden states."""
    B, Lt = tokens.shape
    x = L.embed(params["embed"], tokens, scale=cfg.embed_scale)
    positions = jnp.broadcast_to(jnp.arange(Lt), (B, Lt))

    def body(x, p_layer):
        h = L.rmsnorm(p_layer["ln_self"], x, cfg.norm_eps)
        out, _ = attention(p_layer["self_attn"], cfg, h, positions,
                           sparsity=cfg.attn_sparsity)
        x = x + out
        h = L.rmsnorm(p_layer["ln_cross"], x, cfg.norm_eps)
        out, _ = attention(p_layer["cross_attn"], cfg, h, positions,
                           cross_src=enc_out, sparsity=cfg.attn_sparsity)
        x = x + out
        h = L.rmsnorm(p_layer["ln_mlp"], x, cfg.norm_eps)
        return x + L.mlp(p_layer["mlp"], h, gated=cfg.mlp_gated,
                         sparsity=cfg.mlp_sparsity), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["decoder"])
    return L.rmsnorm(params["ln_dec_final"], x, cfg.norm_eps)


def decode_train(params: Params, cfg: ModelConfig, tokens: Array,
                 enc_out: Array) -> Array:
    x = decode_hidden(params, cfg, tokens, enc_out)
    return L.unembed(params["embed"], x, softcap=cfg.final_softcap)


def encdec_apply(params: Params, cfg: ModelConfig, src: Array,
                 tokens: Array) -> Array:
    """Teacher-forcing forward: (frames, target tokens) → logits."""
    return decode_train(params, cfg, tokens, encode(params, cfg, src))


def encdec_loss(params: Params, cfg: ModelConfig, src: Array, tokens: Array,
                labels: Array) -> Array:
    from repro.models.transformer import chunked_ce
    x = decode_hidden(params, cfg, tokens, encode(params, cfg, src))
    return chunked_ce(x, params["embed"], labels, cfg)


# ---------------------------------------------------------------------------
# Serving: prefill (encode + cache cross K/V + decoder prompt) and decode
# ---------------------------------------------------------------------------

def _cross_kv(p_layer: Params, cfg: ModelConfig, enc_out: Array):
    """Project encoder output to one decoder layer's cross K/V."""
    from repro.core.sparse_linear import apply_linear
    B, Ls, _ = enc_out.shape
    k = apply_linear(enc_out, p_layer["cross_attn"]["wk"], cfg.attn_sparsity)
    v = apply_linear(enc_out, p_layer["cross_attn"]["wv"], cfg.attn_sparsity)
    k = k.reshape(B, Ls, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Ls, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = L.rmsnorm(p_layer["cross_attn"]["k_norm"], k, cfg.norm_eps)
    return k, v


def _dec_step_body(p_layer, cfg: ModelConfig, x: Array, positions: Array,
                   self_cache: Params, cross_k: Array, cross_v: Array,
                   cache_pos) -> Tuple[Array, Params]:
    """One decoder layer against cached self/cross K/V."""
    from repro.core.sparse_linear import apply_linear
    B, Lq, _ = x.shape
    h = L.rmsnorm(p_layer["ln_self"], x, cfg.norm_eps)
    out, new_self = attention(p_layer["self_attn"], cfg, h, positions,
                              cache=self_cache, cache_pos=cache_pos,
                              sparsity=cfg.attn_sparsity)
    x = x + out

    h = L.rmsnorm(p_layer["ln_cross"], x, cfg.norm_eps)
    q = apply_linear(h, p_layer["cross_attn"]["wq"], cfg.attn_sparsity)
    q = q.reshape(B, Lq, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rmsnorm(p_layer["cross_attn"]["q_norm"], q, cfg.norm_eps)
    out = _sdpa(cfg, q, cross_k, cross_v, causal=False, window=None)
    out = out.reshape(B, Lq, cfg.q_dim)
    out = apply_linear(out, p_layer["cross_attn"]["wo"], cfg.attn_sparsity)
    x = x + out

    h = L.rmsnorm(p_layer["ln_mlp"], x, cfg.norm_eps)
    x = x + L.mlp(p_layer["mlp"], h, gated=cfg.mlp_gated,
                  sparsity=cfg.mlp_sparsity)
    return x, new_self


def _dec_cached(params: Params, cfg: ModelConfig, tokens: Array,
                cache: Params, cache_pos,
                last_only: bool = False) -> Tuple[Array, Params]:
    B, Lt = tokens.shape
    x = L.embed(params["embed"], tokens, scale=cfg.embed_scale)
    cp = jnp.asarray(cache_pos)
    base = jnp.arange(Lt)[None, :] + (cp[:, None] if cp.ndim == 1 else cp)
    positions = jnp.broadcast_to(base, (B, Lt))

    def body(x, xs):
        p_layer, self_c, ck, cv = xs
        x, new_self = _dec_step_body(p_layer, cfg, x, positions, self_c,
                                     ck, cv, cache_pos)
        return x, new_self

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"], cache["self"],
                  cache["cross"]["k"], cache["cross"]["v"]))
    x = L.rmsnorm(params["ln_dec_final"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = L.unembed(params["embed"], x, softcap=cfg.final_softcap)
    return logits, {"self": new_self, "cross": cache["cross"]}


def encdec_prefill(params: Params, cfg: ModelConfig, src: Array,
                   prompt: Array, cache: Params) -> Tuple[Array, Params]:
    """Encode source, fill cross K/V, prefill decoder self cache with the
    prompt; returns last-position logits + the serving cache."""
    enc_out = encode(params, cfg, src)

    def kv_layer(p_layer):
        return _cross_kv(p_layer, cfg, enc_out)

    ck, cv = jax.vmap(kv_layer)(params["decoder"])     # (nl, B, Ls, Hk, D)
    cache = {"self": cache["self"],
             "cross": {"k": ck.astype(cache["cross"]["k"].dtype),
                       "v": cv.astype(cache["cross"]["v"].dtype)}}
    logits, cache = _dec_cached(params, cfg, prompt, cache,
                                jnp.zeros((), jnp.int32), last_only=True)
    return logits[:, -1], cache


def encdec_decode_step(params: Params, cfg: ModelConfig, token: Array,
                       cache: Params, pos: Array) -> Tuple[Array, Params]:
    logits, cache = _dec_cached(params, cfg, token[:, None], cache, pos)
    return logits[:, 0], cache


def encdec_decode_block(params: Params, cfg: ModelConfig, tokens: Array,
                        cache: Params, pos: Array) -> Tuple[Array, Params]:
    """Multi-token decode-shaped forward (the speculative verify step):
    ``tokens (B, T)`` against the cached self/cross K/V at per-slot
    positions ``pos (B,)`` — logits for every block position."""
    return _dec_cached(params, cfg, tokens, cache, pos)
