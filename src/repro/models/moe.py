"""Mixture-of-Experts block (qwen2-moe / dbrx style).

Top-k routed experts + optional always-on shared experts.  Dispatch is the
dense one-hot einsum formulation — the standard SPMD-friendly form: XLA
partitions the ``(tokens, experts)`` contractions over the mesh and inserts
the all-to-all/all-gather collectives itself, which the roofline pass then
measures.  Expert weights are stacked ``(E, d, ff)`` so they shard either
over the expert axis (EP, ``moe_sharding="ep"``) or expert-internally
(TP, ``"tp"`` — used when E doesn't divide the model-axis extent, e.g.
qwen2-moe's 60 experts on a 16-wide axis).

The paper's technique composes *inside* each expert: ``expert_sparsity``
declares an N:M or block format for the expert FFN matmuls — expert-level
routing is the coarse sparsity the paper contrasts with, intra-expert
semi-structured/unstructured sparsity is the fine-grained kind it
accelerates (DESIGN.md §5).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.sparse_linear import DENSE, SparsityConfig, apply_linear
from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array
Params = Dict[str, Any]


def init_moe(rng: Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, ff, E = cfg.d_model, cfg.d_expert, cfg.n_experts
    ks = jax.random.split(rng, 5)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    def stack(key, shape):
        return (jax.random.normal(key, shape, jnp.float32) * scale
                ).astype(dtype)

    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32)
                   * scale).astype(jnp.float32),       # router in f32
        "w_in": stack(ks[1], (E, d, ff)),
        "w_gate": stack(ks[2], (E, d, ff)),
        "w_out": stack(ks[3], (E, ff, d)),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, ff * cfg.n_shared_experts,
                                 gated=cfg.mlp_gated, dtype=dtype)
    return p


def route_topk(logits: Array, k: int) -> Tuple[Array, Array]:
    """(T, E) router logits → (combine (T, E) float, dispatch (T, E) bool).

    Softmax over the top-k experts only (qwen2-moe/dbrx normalization).
    """
    vals, idx = jax.lax.top_k(logits, k)                       # (T, k)
    gates = jax.nn.softmax(vals, axis=-1)                      # (T, k)
    combine = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], idx].set(gates)
    return combine, combine > 0


def moe(params: Params, cfg: ModelConfig, x: Array,
        sparsity: SparsityConfig = DENSE) -> Tuple[Array, Array]:
    """x (B, L, d) → (out (B, L, d), aux_loss scalar).

    Dense dispatch: every expert sees every token's activation masked by its
    combine weight — compute is ``O(T·E·d·ff)`` *in the einsum expression*
    but XLA's SPMD partitioner shards E over the mesh so per-device compute
    is ``O(T·E/ep·…)``; with top-k ≪ E the optimized path (§Perf) replaces
    this with a sorted-scatter dispatch.  Aux loss is the standard
    load-balancing term (Switch-style).
    """
    B, Lq, d = x.shape
    T = B * Lq
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ params["router"])       # (T, E)
    combine, dispatch = route_topk(logits, cfg.top_k)

    # load-balance aux loss: E * sum_e (frac_tokens_e * frac_prob_e)
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(dispatch.astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(frac_tokens * frac_probs)

    # expert FFNs (gated): h_e = silu(x W_g,e) * (x W_i,e); y_e = h_e W_o,e
    xe = xt.astype(params["w_in"].dtype)
    h_in = jnp.einsum("td,edf->etf", xe, params["w_in"])
    h_gate = jnp.einsum("td,edf->etf", xe, params["w_gate"])
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(h_in.dtype) * h_in
    y_e = jnp.einsum("etf,efd->etd", h, params["w_out"])       # (E, T, d)
    y = jnp.einsum("etd,te->td", y_e.astype(jnp.float32),
                   combine).astype(x.dtype)

    if cfg.n_shared_experts:
        y = y + L.mlp(params["shared"], xt, gated=cfg.mlp_gated,
                      sparsity=sparsity)
    return y.reshape(B, Lq, d), aux


def moe_grouped(params: Params, cfg: ModelConfig, x: Array,
                sparsity: SparsityConfig = DENSE,
                capacity_factor: float = 1.25,
                group_size: int = 4096) -> Tuple[Array, Array]:
    """GShard-style capacity-based dispatch: FLOPs ∝ top_k/E of dense.

    Tokens are cut into groups of ``S = group_size``; per group each expert
    accepts at most ``C = ceil(S·top_k/E·capacity_factor)`` tokens (overflow
    is dropped — standard Switch behaviour; the aux loss keeps the router
    balanced so drops are rare).  Dispatch/combine are one-hot einsums —
    the exact GShard formulation XLA's SPMD partitioner turns into
    all-to-alls over the expert axis.  This is the production path; the
    dense dispatch above is the correctness baseline (§Perf records the
    useful-FLOP ratio jump between them).
    """
    B, Lq, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * Lq
    S = min(group_size, T)
    if T % S:
        S = T            # fall back to one group (small inputs)
    G = T // S
    C = max(int(math.ceil(S * k / E * capacity_factor)), 1)

    xt = x.reshape(G, S, d)
    logits = xt.astype(jnp.float32) @ params["router"]          # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k gates, normalized over the chosen k (qwen2-moe/dbrx convention)
    vals, eidx = jax.lax.top_k(logits, k)                       # (G, S, k)
    gates = jax.nn.softmax(vals, axis=-1)

    # position of each (token, slot) in its expert's queue: rank tokens by
    # slot-major order (slot 0 assignments first — they carry the largest
    # gates, so overflow drops the least important routes).
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32)           # (G, S, k, E)
    slotmajor = onehot.transpose(0, 2, 1, 3).reshape(G, k * S, E)
    pos_sm = jnp.cumsum(slotmajor, axis=1) - slotmajor          # (G, kS, E)
    pos = pos_sm.reshape(G, k, S, E).transpose(0, 2, 1, 3)      # (G, S, k, E)
    pos = jnp.sum(pos * onehot, axis=-1)                        # (G, S, k)
    keep = pos < C
    gates = gates * keep

    # aux loss (per group, Switch-style), computed before dropping
    frac_tokens = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=2), axis=1)
    frac_probs = jnp.mean(probs, axis=1)                        # (G, E)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    # dispatch one-hot (G, S, E, C): token s → (expert, position)
    disp = (jax.nn.one_hot(eidx, E, dtype=jnp.float32)[..., None]
            * jax.nn.one_hot(pos, C, dtype=jnp.float32)[..., None, :]
            * keep[..., None, None].astype(jnp.float32))        # (G,S,k,E,C)
    comb = jnp.sum(disp * gates[..., None, None], axis=2)       # (G, S, E, C)
    disp = jnp.sum(disp, axis=2)

    xe = jnp.einsum("gsec,gsd->gecd", disp.astype(xt.dtype), xt)  # (G,E,C,d)
    h_in = jnp.einsum("gecd,edf->gecf", xe, params["w_in"])
    h_gate = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(h_in.dtype) * h_in
    y_e = jnp.einsum("gecf,efd->gecd", h, params["w_out"])      # (G, E, C, d)
    y = jnp.einsum("gsec,gecd->gsd", comb.astype(jnp.float32),
                   y_e.astype(jnp.float32)).astype(x.dtype)

    y = y.reshape(B, Lq, d)
    if cfg.n_shared_experts:
        y = y + L.mlp(params["shared"], x, gated=cfg.mlp_gated,
                      sparsity=sparsity)
    return y, aux


def moe_sorted(params: Params, cfg: ModelConfig, x: Array,
               sparsity: SparsityConfig = DENSE,
               capacity_factor: float = 1.25,
               group_size: int = 4096) -> Tuple[Array, Array]:
    """Group-local sort-based capacity dispatch — the production path.

    Two known failure modes shape this implementation:
      * the GShard one-hot dispatch tensor ``(G, S, E, C)`` is ~80 TB at
        train_4k scale (memory);
      * a *flat* sort-based dispatch scatters tokens into a global
        ``(E·C, d)`` buffer whose slot indices ignore data-shard locality
        — GSPMD then lowers the scatter/gather to full-tensor all-reduces
        (measured: 12.9 GB × layers × µbatches on dbrx, EXPERIMENTS.md
        §Perf prelude).

    The fix is GShard's *grouping* without its one-hot: tokens are cut
    into groups of ``S = group_size`` aligned with the data axis
    (``constrain(xg, BATCH, ...)``); routing, capacity (per group,
    ``C = S·k/E·cf``), scatter and combine all stay **within a group** —
    zero cross-shard traffic; the only resharding is the expert einsum
    itself, where GSPMD converts group-sharding → expert-sharding (the
    EP all-to-all, exactly the collective the paper's Table-I "HW"
    accelerators avoid and CPU+HW designs pay).

    Exact-equal to the dense dispatch when capacity is ample (tested).
    """
    from repro.distributed.annotate import batch_axes, constrain

    BATCH = batch_axes()

    B, Lq, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * Lq
    S = min(group_size, T)
    while T % S:
        S //= 2
    G = T // S
    C = max(int(math.ceil(S * k / E * capacity_factor)), 1)

    xg = constrain(x.reshape(G, S, d), BATCH, None, None)
    logits = xg.astype(jnp.float32) @ params["router"]          # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, eidx = jax.lax.top_k(logits, k)                       # (G, S, k)
    gates = jax.nn.softmax(vals, axis=-1)

    # aux loss (Switch-style) before dropping
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.float32)         # (G, S, k, E)
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    # per-group (token, slot) pairs; slot-major order so slot-0 (largest
    # gate) routes win capacity — same drop policy as moe_grouped
    e_flat = eidx.transpose(0, 2, 1).reshape(G, k * S)          # (G, kS)
    t_flat = jnp.tile(jnp.arange(S), k)[None].repeat(G, 0)
    g_flat = gates.transpose(0, 2, 1).reshape(G, k * S)
    order = jnp.argsort(e_flat, axis=1, stable=True)
    e_s = jnp.take_along_axis(e_flat, order, axis=1)
    t_s = jnp.take_along_axis(t_flat, order, axis=1)
    g_s = jnp.take_along_axis(g_flat, order, axis=1)
    # position within each expert's run (per group)
    counts = jnp.sum(jax.nn.one_hot(e_s, E, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts                # (G, E)
    pos = jnp.arange(k * S)[None] - jnp.take_along_axis(starts, e_s, axis=1)
    keep = pos < C
    slot = e_s * C + jnp.where(keep, pos, 0)                    # (G, kS)

    # group-local dispatch: (G, E·C, d) buffer — no cross-group indices.
    # All payload tensors stay bf16 (dispatch/combine in f32 doubles the
    # collective bytes of the EP resharding — §Perf cell B iteration 4);
    # gates/aux keep f32.
    contrib = jnp.where(keep[..., None],
                        jnp.take_along_axis(
                            xg, t_s[..., None], axis=1), 0)     # (G, kS, d)
    contrib = constrain(contrib, BATCH, None, None)
    buf = jnp.zeros((G, E * C, d), xg.dtype)
    gidx = jnp.arange(G)[:, None].repeat(k * S, 1)
    buf = buf.at[gidx, slot].add(contrib)
    buf = constrain(buf, BATCH, None, None)

    # EP compute layout (constrained IN-LOOP — input shardings don't steer
    # GSPMD inside the layer scan, measured §Perf cell B): weights gather
    # to (e:model, ·, ·) per layer (FSDP), the hidden dual-shards
    # (g:data × e:model) so both expert einsums are collective-free; the
    # only activation collective left is the combine's e-gather.
    from repro.distributed.annotate import MODEL
    ep = MODEL if cfg.moe_sharding == "ep" else None
    w_in = constrain(params["w_in"], ep, None, None)
    w_gate = constrain(params["w_gate"], ep, None, None)
    w_out = constrain(params["w_out"], ep, None, None)
    xe = buf.reshape(G, E, C, d).astype(w_in.dtype)
    xe = constrain(xe, BATCH, None, None, None)
    h_in = jnp.einsum("gecd,edf->gecf", xe, w_in)
    h_gate = jnp.einsum("gecd,edf->gecf", xe, w_gate)
    h_in = constrain(h_in, BATCH, ep, None, None)
    h_gate = constrain(h_gate, BATCH, ep, None, None)
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(h_in.dtype) * h_in
    y_e = jnp.einsum("gecf,efd->gecd", h, w_out)                # (G, E, C, d)
    y_e = constrain(y_e, BATCH, None, None, None)

    # group-local combine — payloads bf16, gate weighting in the payload
    # dtype (accumulation error vs the f32 path is bf16-noise; tested
    # against the dense dispatch)
    y_buf = constrain(y_e.reshape(G, E * C, d), BATCH, None, None)
    routed = jnp.take_along_axis(y_buf, slot[..., None], axis=1)
    routed = jnp.where(keep[..., None],
                       routed * g_s[..., None].astype(y_buf.dtype), 0)
    routed = constrain(routed, BATCH, None, None)
    y = jnp.zeros((G, S, d), y_buf.dtype)
    y = y.at[gidx, t_s].add(routed)
    y = y.astype(x.dtype).reshape(B, Lq, d)

    if cfg.n_shared_experts:
        y = y + L.mlp(params["shared"], x, gated=cfg.mlp_gated,
                      sparsity=sparsity)
    return y, aux


def moe_sparse_expert(params: Params, cfg: ModelConfig, x: Array,
                      sparsity: SparsityConfig) -> Tuple[Array, Array]:
    """Variant whose expert weights are *packed* sparse formats.

    ``params["w_in"/"w_gate"/"w_out"]`` are pack pytrees with a leading E
    axis; each expert matmul dispatches through the sparse kernels via a
    vmap over experts.  Used by the sparse-MoE configs (paper technique on
    expert FFNs).
    """
    B, Lq, d = x.shape
    T = B * Lq
    xt = x.reshape(T, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    combine, dispatch = route_topk(logits, cfg.top_k)
    probs = jax.nn.softmax(logits, axis=-1)
    aux = cfg.n_experts * jnp.sum(
        jnp.mean(dispatch.astype(jnp.float32), 0) * jnp.mean(probs, 0))

    def one_expert(w_in, w_gate, w_out):
        h = apply_linear(xt, w_in, sparsity)
        g = apply_linear(xt, w_gate, sparsity)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
        return apply_linear(h, w_out, sparsity)               # (T, d)

    y_e = jax.vmap(one_expert)(params["w_in"], params["w_gate"],
                               params["w_out"])                # (E, T, d)
    y = jnp.einsum("etd,te->td", y_e.astype(jnp.float32),
                   combine).astype(x.dtype)
    if cfg.n_shared_experts:
        y = y + L.mlp(params["shared"], xt, gated=cfg.mlp_gated,
                      sparsity=sparsity)
    return y.reshape(B, Lq, d), aux
