"""Shared neural building blocks (pure-JAX pytrees, no framework).

Every projection goes through ``core.sparse_linear.apply_linear`` so the
paper's sparsity formats are available to *all* model families via config.
Params are nested dicts of arrays; init functions mirror apply functions.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sparse_linear import (DENSE, SparsityConfig, apply_linear,
                                      init_linear)

Array = jax.Array
Params = Dict[str, Any]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}      # (1 + scale) convention


def rmsnorm(params: Params, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) \
        + params["bias"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(rng: Array, vocab_padded: int, d: int,
                   dtype=jnp.bfloat16) -> Array:
    e = jax.random.normal(rng, (vocab_padded, d), jnp.float32)
    return (e / math.sqrt(d)).astype(dtype)


def embed(table: Array, tokens: Array, scale: bool = False) -> Array:
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(table.shape[-1]), x.dtype)
    return x


def unembed(table: Array, x: Array, softcap: Optional[float] = None) -> Array:
    logits = jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10_000.0,
               mrope_sections: Optional[Tuple[int, int, int]] = None) -> Array:
    """Rotate ``x (..., L, H, D)`` by ``positions``.

    ``positions``: (..., L) int32 for standard RoPE, or (..., L, 3) for
    M-RoPE (qwen2-vl: temporal/height/width position triples; the head dim's
    frequency slots are partitioned into ``mrope_sections`` groups, each
    rotated by its own position component).  Text-only inputs pass identical
    triples, which reduces exactly to standard RoPE.
    """
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                        # (D/2,)
    if mrope_sections is None:
        if positions.ndim == x.ndim - 2:              # (..., L)
            ang = positions[..., None].astype(jnp.float32) * inv  # (...,L,D/2)
        else:
            raise ValueError("standard RoPE expects (..., L) positions")
    else:
        if positions.shape[-1] != 3:
            raise ValueError("M-RoPE expects (..., L, 3) positions")
        s0, s1, s2 = mrope_sections
        if (s0 + s1 + s2) != D // 2:
            raise ValueError(f"mrope sections {mrope_sections} != D/2={D//2}")
        sect = jnp.concatenate([jnp.zeros((s0,), jnp.int32),
                                jnp.ones((s1,), jnp.int32),
                                2 * jnp.ones((s2,), jnp.int32)])
        # per-frequency-slot position component: (..., L, D/2)
        pos = positions.astype(jnp.float32)[..., sect]
        ang = pos * inv                               # (..., L, D/2)
    sin = jnp.sin(ang)[..., None, :]                  # (..., L, 1, D/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated / plain), sparse-format aware
# ---------------------------------------------------------------------------

def init_mlp(rng: Array, d: int, ff: int, gated: bool = True,
             dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(rng, 3)
    p = {"w_in": init_linear(ks[0], d, ff, dtype),
         "w_out": init_linear(ks[1], ff, d, dtype)}
    if gated:
        p["w_gate"] = init_linear(ks[2], d, ff, dtype)
    return p


def mlp(params: Params, x: Array, gated: bool = True,
        sparsity: SparsityConfig = DENSE) -> Array:
    h = apply_linear(x, params["w_in"], sparsity)
    if gated:
        g = apply_linear(x, params["w_gate"], sparsity)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    return apply_linear(h, params["w_out"], sparsity)


def init_dense(rng: Array, K: int, N: int, dtype=jnp.bfloat16) -> Array:
    return init_linear(rng, K, N, dtype)
