"""Model zoo — one dispatch API over every assigned architecture family.

``family(cfg)`` routes to the right assembly:
  * ``"lm"``     — decoder-only transformer (dense / MoE / VLM-audio stubs)
  * ``"hybrid"`` — mamba2 / zamba2 (SSM trunk ± shared attention)
  * ``"encdec"`` — seamless (encoder-decoder)

Batch conventions (what ``data.pipeline`` emits and ``input_specs``
abstracts):
  lm      : {"tokens" (B, L) i32, "labels" (B, L) i32}   — or "embeds"
            (B, L, d) bf16 for input_mode="embeds" frontend stubs
  hybrid  : {"tokens", "labels"}
  encdec  : {"src" (B, Ls, d) bf16, "tokens" (B, Lt), "labels" (B, Lt)}

The launcher, trainer and server only speak this API — architecture
differences live entirely behind it.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import transformer as TR
from repro.models.attention import init_kv_cache
from repro.models.config import LayerKind, ModelConfig

Array = jax.Array
Params = Dict[str, Any]


def family(cfg: ModelConfig) -> str:
    if cfg.is_encoder_decoder:
        return "encdec"
    if cfg.uses_mamba:
        return "hybrid"
    return "lm"


# ---------------------------------------------------------------------------
# Init / loss / forward
# ---------------------------------------------------------------------------

def init_model(rng: Array, cfg: ModelConfig) -> Params:
    f = family(cfg)
    if f == "encdec":
        return ED.init_encdec(rng, cfg)
    if f == "hybrid":
        return HY.init_hybrid_lm(rng, cfg)
    return TR.init_lm(rng, cfg)


def model_loss(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
               aux_weight: float = 0.01) -> Array:
    f = family(cfg)
    if f == "encdec":
        return ED.encdec_loss(params, cfg, batch["src"], batch["tokens"],
                              batch["labels"])
    if f == "hybrid":
        return HY.hybrid_loss(params, cfg, batch["tokens"], batch["labels"])
    inputs = batch.get("embeds", batch.get("tokens"))
    x, _, aux = TR.lm_hidden(params, cfg, inputs)
    table = params.get("unembed", params["embed"])
    return TR.chunked_ce(x, table, batch["labels"], cfg) + aux_weight * aux


def model_logits(params: Params, cfg: ModelConfig,
                 batch: Dict[str, Array]) -> Array:
    f = family(cfg)
    if f == "encdec":
        return ED.encdec_apply(params, cfg, batch["src"], batch["tokens"])
    if f == "hybrid":
        return HY.hybrid_apply(params, cfg, batch["tokens"])[0]
    inputs = batch.get("embeds", batch.get("tokens"))
    return TR.lm_apply(params, cfg, inputs)[0]


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: Optional[int] = None,
               dtype=jnp.bfloat16) -> Params:
    f = family(cfg)
    if f == "encdec":
        return ED.init_encdec_cache(cfg, batch, max_len,
                                    src_len or max_len, dtype)
    if f == "hybrid":
        return HY.init_hybrid_cache(cfg, batch, max_len, dtype)
    return init_kv_cache(cfg, batch, max_len, dtype=dtype)


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
            cache: Params) -> Tuple[Array, Params]:
    """Prompt → (last-position logits, filled cache)."""
    f = family(cfg)
    if f == "encdec":
        return ED.encdec_prefill(params, cfg, batch["src"], batch["tokens"],
                                 cache)
    if f == "hybrid":
        return HY.hybrid_prefill(params, cfg, batch["tokens"], cache)
    inputs = batch.get("embeds", batch.get("tokens"))
    return TR.lm_prefill(params, cfg, inputs, cache)


def decode_step(params: Params, cfg: ModelConfig, token: Array,
                cache: Params, pos: Array) -> Tuple[Array, Params]:
    """One token in, next-token logits + updated cache out.

    ``pos`` is either a scalar (all sequences share one write offset —
    the wave-decode posture) or a ``(B,)`` vector of *per-slot* positions
    (continuous batching: each slot advances independently; rope, the
    cache write and the kv-length mask all follow the per-slot value).
    """
    f = family(cfg)
    if f == "encdec":
        return ED.encdec_decode_step(params, cfg, token, cache, pos)
    if f == "hybrid":
        return HY.hybrid_decode_step(params, cfg, token, cache, pos)
    return TR.lm_decode_step(params, cfg, token, cache, pos)


def blank_slot_cache(cache: Params, batch: int = 1) -> Params:
    """A zeroed copy of ``cache`` with the batch axis (axis 1 on every
    leaf) shrunk to ``batch`` — the scratch cache a per-slot prefill
    fills before :func:`merge_cache_slot` writes it into the shared one."""
    return jax.tree.map(
        lambda l: jnp.zeros(l.shape[:1] + (batch,) + l.shape[2:], l.dtype),
        cache)


def merge_cache_slot(cache: Params, slot_cache: Params, slot: Array) -> Params:
    """Write a batch-1 cache into slot ``slot`` of a shared cache.

    Every cache leaf across all families carries batch on axis 1
    (KV: (nl, B, S, Hk, D); SSM conv/state: (nl, B, ...); encdec
    self/cross: (nl, B, S, Hk, D)), so the merge is one
    ``dynamic_update_slice_in_dim`` per leaf — the cache-side half of
    per-slot prefill (continuous refill without draining the batch).
    """
    return jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_slice_in_dim(
            full, one.astype(full.dtype), slot, axis=1), cache, slot_cache)
