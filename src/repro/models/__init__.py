"""Model zoo — one dispatch API over every assigned architecture family.

``family(cfg)`` routes to the right assembly:
  * ``"lm"``     — decoder-only transformer (dense / MoE / VLM-audio stubs)
  * ``"hybrid"`` — mamba2 / zamba2 (SSM trunk ± shared attention)
  * ``"encdec"`` — seamless (encoder-decoder)

Batch conventions (what ``data.pipeline`` emits and ``input_specs``
abstracts):
  lm      : {"tokens" (B, L) i32, "labels" (B, L) i32}   — or "embeds"
            (B, L, d) bf16 for input_mode="embeds" frontend stubs
  hybrid  : {"tokens", "labels"}
  encdec  : {"src" (B, Ls, d) bf16, "tokens" (B, Lt), "labels" (B, Lt)}

The launcher, trainer and server only speak this API — architecture
differences live entirely behind it.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import transformer as TR
from repro.models.attention import (init_kv_cache, init_paged_kv_cache,
                                    paged_max_pages)
from repro.models.config import LayerKind, ModelConfig

Array = jax.Array
Params = Dict[str, Any]


def family(cfg: ModelConfig) -> str:
    if cfg.is_encoder_decoder:
        return "encdec"
    if cfg.uses_mamba:
        return "hybrid"
    return "lm"


# ---------------------------------------------------------------------------
# Init / loss / forward
# ---------------------------------------------------------------------------

def init_model(rng: Array, cfg: ModelConfig) -> Params:
    f = family(cfg)
    if f == "encdec":
        return ED.init_encdec(rng, cfg)
    if f == "hybrid":
        return HY.init_hybrid_lm(rng, cfg)
    return TR.init_lm(rng, cfg)


def model_loss(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
               aux_weight: float = 0.01) -> Array:
    f = family(cfg)
    if f == "encdec":
        return ED.encdec_loss(params, cfg, batch["src"], batch["tokens"],
                              batch["labels"])
    if f == "hybrid":
        return HY.hybrid_loss(params, cfg, batch["tokens"], batch["labels"])
    inputs = batch.get("embeds", batch.get("tokens"))
    x, _, aux = TR.lm_hidden(params, cfg, inputs)
    table = params.get("unembed", params["embed"])
    return TR.chunked_ce(x, table, batch["labels"], cfg) + aux_weight * aux


def model_logits(params: Params, cfg: ModelConfig,
                 batch: Dict[str, Array]) -> Array:
    f = family(cfg)
    if f == "encdec":
        return ED.encdec_apply(params, cfg, batch["src"], batch["tokens"])
    if f == "hybrid":
        return HY.hybrid_apply(params, cfg, batch["tokens"])[0]
    inputs = batch.get("embeds", batch.get("tokens"))
    return TR.lm_apply(params, cfg, inputs)[0]


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: Optional[int] = None,
               dtype=jnp.bfloat16, page_size: int = 0,
               num_pages: int = 0) -> Params:
    """Serving cache for any family.

    ``page_size > 0`` selects the paged layout for every attention KV
    subtree (lm: the whole cache; hybrid: the shared-attention ``kv``;
    encdec: the decoder ``self`` cache — cross K/V is decode-invariant
    and per-slot fixed-size, so it stays monolithic).  ``num_pages``
    sizes the shared pool (0 → full capacity, see
    :func:`attention.init_paged_kv_cache`).
    """
    f = family(cfg)
    if f == "encdec":
        return ED.init_encdec_cache(cfg, batch, max_len,
                                    src_len or max_len, dtype,
                                    page_size=page_size,
                                    num_pages=num_pages)
    if f == "hybrid":
        return HY.init_hybrid_cache(cfg, batch, max_len, dtype,
                                    page_size=page_size,
                                    num_pages=num_pages)
    if page_size:
        return init_paged_kv_cache(cfg, batch, max_len, page_size,
                                   num_pages, dtype=dtype)
    return init_kv_cache(cfg, batch, max_len, dtype=dtype)


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
            cache: Params) -> Tuple[Array, Params]:
    """Prompt → (last-position logits, filled cache)."""
    f = family(cfg)
    if f == "encdec":
        src = batch.get("src")
        if src is None:
            # serving: the Engine hands decoder tokens only — condition
            # on a null (all-zeros) source sized to the cross cache's
            # source axis, so the jitted prefill's cache shapes match
            # the initialized cache exactly
            src = jnp.zeros((batch["tokens"].shape[0],
                             cache["cross"]["k"].shape[2], cfg.d_model),
                            cache["cross"]["k"].dtype)
        return ED.encdec_prefill(params, cfg, src, batch["tokens"],
                                 cache)
    if f == "hybrid":
        return HY.hybrid_prefill(params, cfg, batch["tokens"], cache)
    inputs = batch.get("embeds", batch.get("tokens"))
    return TR.lm_prefill(params, cfg, inputs, cache)


def decode_step(params: Params, cfg: ModelConfig, token: Array,
                cache: Params, pos: Array) -> Tuple[Array, Params]:
    """One token in, next-token logits + updated cache out.

    ``pos`` is either a scalar (all sequences share one write offset —
    the wave-decode posture) or a ``(B,)`` vector of *per-slot* positions
    (continuous batching: each slot advances independently; rope, the
    cache write and the kv-length mask all follow the per-slot value).
    """
    f = family(cfg)
    if f == "encdec":
        return ED.encdec_decode_step(params, cfg, token, cache, pos)
    if f == "hybrid":
        return HY.hybrid_decode_step(params, cfg, token, cache, pos)
    return TR.lm_decode_step(params, cfg, token, cache, pos)


def decode_block(params: Params, cfg: ModelConfig, tokens: Array,
                 cache: Params, pos: Array, collect_states: bool = False
                 ) -> Tuple[Array, Params, Optional[Params]]:
    """Multi-token decode-shaped forward — the speculative *verify* step.

    ``tokens (B, T)`` are written at per-slot positions ``pos (B,)``
    (ragged offsets; causal attention within the block) and scored in ONE
    batched forward: logits come back for every block position
    ``(B, T, vocab_padded)`` instead of the last one only.  Works against
    monolithic and paged caches alike — the attention layer's scatter /
    mask math already carries ``Lq > 1`` at vector ``cache_pos``.

    Returns ``(logits, new_cache, snapshots)``.  ``snapshots`` is ``None``
    for the purely positional families (lm, encdec — rollback there is
    just "don't advance ``cache_pos``"); for the hybrid family with
    ``collect_states=True`` it holds per-position recurrent-state
    snapshots (see :func:`recurrent_state` / :func:`select_recurrent`).
    """
    f = family(cfg)
    if f == "encdec":
        logits, new_cache = ED.encdec_decode_block(params, cfg, tokens,
                                                   cache, pos)
        return logits, new_cache, None
    if f == "hybrid":
        return HY.hybrid_decode_block(params, cfg, tokens, cache, pos,
                                      collect=collect_states)
    logits, new_cache = TR.lm_decode_block(params, cfg, tokens, cache, pos)
    return logits, new_cache, None


# --- recurrent (non-positional) cache state -------------------------------
#
# KV caches roll back by position truncation: rows past ``cache_pos`` are
# dead by masking, so speculative rejection costs nothing.  Recurrent
# state (the hybrid family's SSM conv/state) is order-dependent — these
# helpers snapshot it before a drafted block, restore it for the verify
# forward, and select the per-position snapshot matching the accepted
# prefix afterwards.

def recurrent_state(cache: Params) -> Optional[Params]:
    """The order-dependent part of a serving cache (``None`` when the
    family is purely positional)."""
    if isinstance(cache, dict) and "ssm" in cache:
        return cache["ssm"]
    return None


def set_recurrent_state(cache: Params, state: Optional[Params]) -> Params:
    """Replace the recurrent subtree of ``cache`` with ``state``."""
    if state is None:
        return cache
    return {**cache, "ssm": state}


def select_recurrent(snapshots: Params, idx: Array) -> Params:
    """Pick per-slot snapshots: leaves ``(nl, B, T, ...)`` × ``idx (B,)``
    → ``(nl, B, ...)`` — the state after block position ``idx[b]``."""

    def pick(leaf):
        ix = idx.reshape((1, -1) + (1,) * (leaf.ndim - 2))
        ix = jnp.broadcast_to(ix, leaf.shape[:2] + (1,) + leaf.shape[3:])
        return jnp.take_along_axis(leaf, ix, axis=2)[:, :, 0]

    return jax.tree.map(pick, snapshots)


def where_slot(mask: Array, a: Params, b: Params) -> Params:
    """Per-slot select between two cache-state trees whose leaves carry
    the batch on axis 1 (``(nl, B, ...)``): slot ``i`` takes ``a`` where
    ``mask[i]`` else ``b``."""

    def sel(la, lb):
        m = mask.reshape((1, -1) + (1,) * (la.ndim - 2))
        return jnp.where(m, la, lb)

    return jax.tree.map(sel, a, b)


def _is_paged(tree: Any) -> bool:
    return isinstance(tree, dict) and "ptab" in tree


def blank_slot_cache(cache: Params, batch: int = 1) -> Params:
    """The scratch cache a per-slot prefill fills before
    :func:`merge_cache_slot` writes it into the shared one.

    Monolithic subtrees get a zeroed copy with the batch axis (axis 1 on
    every leaf) shrunk to ``batch``.  Paged subtrees share the page
    *pool* by reference (per-slot prefill scatters straight into it —
    the slot's pages are disjoint from every live slot's) and get a
    batch-``batch`` all-null page table; the engine stamps the slot's
    assigned pages into it (:func:`set_page_table`) before prefilling.
    """
    if _is_paged(cache):
        mp = cache["ptab"].shape[-1]
        nl = cache["ptab"].shape[0]
        return {"kp": cache["kp"], "vp": cache["vp"],
                "ptab": jnp.zeros((nl, batch, mp), jnp.int32)}
    if isinstance(cache, dict):
        return {k: blank_slot_cache(v, batch) for k, v in cache.items()}
    return jnp.zeros(cache.shape[:1] + (batch,) + cache.shape[2:],
                     cache.dtype)


def merge_cache_slot(cache: Params, slot_cache: Params, slot: Array) -> Params:
    """Write a batch-1 cache into slot ``slot`` of a shared cache.

    Monolithic cache leaves across all families carry batch on axis 1
    (KV: (nl, B, S, Hk, D); SSM conv/state: (nl, B, ...); encdec
    self/cross: (nl, B, S, Hk, D)), so the merge is one
    ``dynamic_update_slice_in_dim`` per leaf — the cache-side half of
    per-slot prefill (continuous refill without draining the batch).
    Paged subtrees already hold the prefill's pool writes (the scratch
    shares the pool); only the slot's page-table row needs merging.
    """
    if _is_paged(cache):
        return {"kp": slot_cache["kp"], "vp": slot_cache["vp"],
                "ptab": jax.lax.dynamic_update_slice_in_dim(
                    cache["ptab"], slot_cache["ptab"], slot, axis=1)}
    if isinstance(cache, dict):
        return {k: merge_cache_slot(cache[k], slot_cache[k], slot)
                for k in cache}
    return jax.lax.dynamic_update_slice_in_dim(
        cache, slot_cache.astype(cache.dtype), slot, axis=1)


def copy_page(cache: Params, src: Array, dst: Array) -> Params:
    """Device-copy one physical page, all layers: the copy-on-write half
    of prefix sharing.

    ``src``/``dst`` are traced page-id scalars into the pool axis of
    every paged subtree (``kp``/``vp`` are ``(nl, pages+1, ps, Hk, D)``).
    The whole page is copied; rows past the divergence point are
    overwritten by the suffix prefill's scatter or dead by kv-length
    masking, so over-copying is harmless.  Non-paged subtrees pass
    through untouched.
    """
    if _is_paged(cache):
        return {"kp": cache["kp"].at[:, dst].set(cache["kp"][:, src]),
                "vp": cache["vp"].at[:, dst].set(cache["vp"][:, src]),
                "ptab": cache["ptab"]}
    if isinstance(cache, dict):
        return {k: copy_page(v, src, dst) for k, v in cache.items()}
    return cache


def set_page_table(cache: Params, table: Array) -> Params:
    """Replace every paged subtree's page table with ``table``.

    ``table`` is ``(B, max_pages)`` int32 (the host allocator's view);
    it is broadcast over the stacked-layers axis of each ``ptab`` leaf.
    The host refreshes the device tables through this before each decode
    chunk (page allocation / slot retirement happen between chunks) and
    stamps a slot's assigned pages into the refill scratch with it.
    """
    if _is_paged(cache):
        pt = cache["ptab"]
        return {"kp": cache["kp"], "vp": cache["vp"],
                "ptab": jnp.broadcast_to(table.astype(jnp.int32)[None],
                                         pt.shape)}
    if isinstance(cache, dict):
        return {k: set_page_table(v, table) for k, v in cache.items()}
    return cache


def page_view(cache: Params, view_pages: Optional[int]) -> Params:
    """Slice every page table to its first ``view_pages`` logical pages.

    The gather-read in :func:`attention.attention` materializes
    ``max_pages * ps`` logical rows per slot; when the host knows no live
    slot extends past ``view_pages`` pages it narrows the view so decode
    attention work scales with *actual* lengths (the compute-side half
    of the paging win).  ``None`` keeps the full view.
    """
    if view_pages is None:
        return cache
    if _is_paged(cache):
        return {"kp": cache["kp"], "vp": cache["vp"],
                "ptab": cache["ptab"][..., :view_pages]}
    if isinstance(cache, dict):
        return {k: page_view(v, view_pages) for k, v in cache.items()}
    return cache


def unpage_view(new_cache: Params, full_cache: Params) -> Params:
    """Undo :func:`page_view` on a model-returned cache: keep the updated
    pools, restore the full-width page tables from ``full_cache`` (decode
    never rewrites the table, so this is lossless)."""
    if _is_paged(new_cache):
        return {"kp": new_cache["kp"], "vp": new_cache["vp"],
                "ptab": full_cache["ptab"]}
    if isinstance(new_cache, dict):
        return {k: unpage_view(new_cache[k], full_cache[k])
                for k in new_cache}
    return new_cache
