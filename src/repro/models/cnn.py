"""The paper's TinyML benchmark models: VGG16, ResNet-56 (CIFAR-10),
MobileNetV2 (VWW), DSCNN (GSC keyword spotting).

Two roles:

1. **Cycle-model inputs** (Fig. 10): :func:`layer_shapes` lists every
   MAC-bearing layer of the *full-size* models as ``cycle_model.LayerShape``
   entries; ``benchmarks/bench_csa_models`` prunes masks of those shapes
   and counts CFU cycles.  Conventions (recorded deviations):
   input channels are padded up to a multiple of 4 (the CFU block width —
   TFLite pads the same way); depthwise convs are modelled as per-channel
   tap streams (9 taps → 12 with always-computed pad lanes).

2. **Runnable JAX models** (Table II): init/apply pairs with a ``width``
   multiplier so reduced versions train in seconds on CPU; the INT7-vs-INT8
   benchmark quantizes their conv/fc weights through ``core.encoding``.
   Normalization is batch-stat BatchNorm (no running stats — deterministic
   for benches; the quantization comparison is invariant to this choice).

Weights layouts: conv HWIO, linear (K, N).  All weight transforms
(mask / quantize-dequantize) are applied *to the params pytree offline*,
so the forward pass is format-agnostic — the same co-design flow as the
LM side.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.cycle_model import LayerShape

Array = jax.Array
Params = Dict[str, Any]


def _pad4(c: int) -> int:
    return ((c + 3) // 4) * 4


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def conv2d(x: Array, w: Array, stride: int = 1, padding: str = "SAME",
           groups: int = 1) -> Array:
    """NHWC conv with HWIO weights."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def batchnorm(p: Params, x: Array, eps: float = 1e-5) -> Array:
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + eps)
    return xn * p["scale"] + p["bias"]


def _init_conv(rng, kh, kw, cin, cout, dtype=jnp.float32) -> Array:
    fan_in = kh * kw * cin
    return (jax.random.normal(rng, (kh, kw, cin, cout), jnp.float32)
            * math.sqrt(2.0 / fan_in)).astype(dtype)


def _init_bn(c) -> Params:
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


def _init_fc(rng, k, n) -> Params:
    return {"w": (jax.random.normal(rng, (k, n), jnp.float32)
                  / math.sqrt(k)),
            "b": jnp.zeros((n,), jnp.float32)}


# ---------------------------------------------------------------------------
# VGG16 (CIFAR-10 variant)
# ---------------------------------------------------------------------------

VGG16_PLAN = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
              512, 512, 512, "M", 512, 512, 512, "M"]


def init_vgg16(rng: Array, num_classes: int = 10, width: float = 1.0,
               in_ch: int = 3) -> Params:
    convs = []
    c = in_ch
    keys = jax.random.split(rng, 32)
    ki = 0
    for v in VGG16_PLAN:
        if v == "M":
            continue
        cout = max(int(v * width), 8)
        convs.append({"w": _init_conv(keys[ki], 3, 3, c, cout),
                      "bn": _init_bn(cout)})
        c = cout
        ki += 1
    return {"convs": convs,
            "fc": _init_fc(keys[ki], c, num_classes)}


def apply_vgg16(p: Params, x: Array) -> Array:
    i = 0
    for v in VGG16_PLAN:
        if v == "M":
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        else:
            layer = p["convs"][i]
            x = jax.nn.relu(batchnorm(layer["bn"], conv2d(x, layer["w"])))
            i += 1
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["fc"]["w"] + p["fc"]["b"]


# ---------------------------------------------------------------------------
# ResNet-56 (CIFAR)
# ---------------------------------------------------------------------------

def init_resnet56(rng: Array, num_classes: int = 10, width: float = 1.0,
                  n_blocks: int = 9, in_ch: int = 3) -> Params:
    widths = [max(int(w * width), 8) for w in (16, 32, 64)]
    keys = iter(jax.random.split(rng, 8 + 6 * n_blocks * 2))
    p: Params = {"stem": {"w": _init_conv(next(keys), 3, 3, in_ch, widths[0]),
                          "bn": _init_bn(widths[0])},
                 "stages": []}
    cin = widths[0]
    for s, cout in enumerate(widths):
        blocks = []
        for b in range(n_blocks):
            stride = 2 if (s > 0 and b == 0) else 1
            blk = {"w1": _init_conv(next(keys), 3, 3, cin, cout),
                   "bn1": _init_bn(cout),
                   "w2": _init_conv(next(keys), 3, 3, cout, cout),
                   "bn2": _init_bn(cout)}
            if stride != 1 or cin != cout:
                blk["proj"] = _init_conv(next(keys), 1, 1, cin, cout)
            blocks.append(blk)
            cin = cout
        p["stages"].append(blocks)
    p["fc"] = _init_fc(next(keys), cin, num_classes)
    return p


def apply_resnet56(p: Params, x: Array) -> Array:
    x = jax.nn.relu(batchnorm(p["stem"]["bn"], conv2d(x, p["stem"]["w"])))
    for si, stage in enumerate(p["stages"]):
        for bi, blk in enumerate(stage):
            stride = 2 if (si > 0 and bi == 0) else 1   # structural, not a leaf
            h = jax.nn.relu(batchnorm(
                blk["bn1"], conv2d(x, blk["w1"], stride=stride)))
            h = batchnorm(blk["bn2"], conv2d(h, blk["w2"]))
            sc = conv2d(x, blk["proj"], stride=stride) \
                if "proj" in blk else x
            x = jax.nn.relu(h + sc)
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["fc"]["w"] + p["fc"]["b"]


# ---------------------------------------------------------------------------
# MobileNetV2 (VWW: 96×96, 2 classes)
# ---------------------------------------------------------------------------

MBV2_PLAN = [  # (expansion t, out channels c, repeats n, stride s)
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
]


def init_mobilenetv2(rng: Array, num_classes: int = 2, width: float = 1.0,
                     in_ch: int = 3) -> Params:
    keys = iter(jax.random.split(rng, 256))

    def ch(c):
        return max(int(c * width), 8)

    p: Params = {"stem": {"w": _init_conv(next(keys), 3, 3, in_ch, ch(32)),
                          "bn": _init_bn(ch(32))},
                 "blocks": []}
    cin = ch(32)
    for t, c, n, s in MBV2_PLAN:
        for i in range(n):
            cout = ch(c)
            hidden = cin * t
            blk: Params = {}
            if t != 1:
                blk["expand"] = {"w": _init_conv(next(keys), 1, 1, cin, hidden),
                                 "bn": _init_bn(hidden)}
            blk["dw"] = {"w": _init_conv(next(keys), 3, 3, 1, hidden),
                         "bn": _init_bn(hidden)}
            blk["project"] = {"w": _init_conv(next(keys), 1, 1, hidden, cout),
                              "bn": _init_bn(cout)}
            p["blocks"].append(blk)
            cin = cout
    head = ch(1280)
    p["head"] = {"w": _init_conv(next(keys), 1, 1, cin, head),
                 "bn": _init_bn(head)}
    p["fc"] = _init_fc(next(keys), head, num_classes)
    return p


def apply_mobilenetv2(p: Params, x: Array) -> Array:
    x = jax.nn.relu6(batchnorm(p["stem"]["bn"],
                               conv2d(x, p["stem"]["w"], stride=2)))
    strides = [s if i == 0 else 1
               for t, c, n, s in MBV2_PLAN for i in range(n)]
    for blk, stride in zip(p["blocks"], strides):
        h = x
        if "expand" in blk:
            h = jax.nn.relu6(batchnorm(blk["expand"]["bn"],
                                       conv2d(h, blk["expand"]["w"])))
        hidden = h.shape[-1]
        h = jax.nn.relu6(batchnorm(
            blk["dw"]["bn"],
            conv2d(h, blk["dw"]["w"], stride=stride, groups=hidden)))
        h = batchnorm(blk["project"]["bn"], conv2d(h, blk["project"]["w"]))
        use_res = stride == 1 and x.shape[-1] == h.shape[-1]
        x = x + h if use_res else h
    x = jax.nn.relu6(batchnorm(p["head"]["bn"], conv2d(x, p["head"]["w"])))
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["fc"]["w"] + p["fc"]["b"]


# ---------------------------------------------------------------------------
# DSCNN (keyword spotting, GSC: 49×10 MFCC input)
# ---------------------------------------------------------------------------

def init_dscnn(rng: Array, num_classes: int = 12, width: float = 1.0,
               n_ds_blocks: int = 4, in_ch: int = 1) -> Params:
    keys = iter(jax.random.split(rng, 32))
    c = max(int(64 * width), 8)
    p: Params = {"stem": {"w": _init_conv(next(keys), 10, 4, in_ch, c),
                          "bn": _init_bn(c)},
                 "blocks": []}
    for _ in range(n_ds_blocks):
        p["blocks"].append({
            "dw": {"w": _init_conv(next(keys), 3, 3, 1, c), "bn": _init_bn(c)},
            "pw": {"w": _init_conv(next(keys), 1, 1, c, c), "bn": _init_bn(c)},
        })
    p["fc"] = _init_fc(next(keys), c, num_classes)
    return p


def apply_dscnn(p: Params, x: Array) -> Array:
    x = jax.nn.relu(batchnorm(p["stem"]["bn"],
                              conv2d(x, p["stem"]["w"], stride=2)))
    for blk in p["blocks"]:
        c = x.shape[-1]
        x = jax.nn.relu(batchnorm(blk["dw"]["bn"],
                                  conv2d(x, blk["dw"]["w"], groups=c)))
        x = jax.nn.relu(batchnorm(blk["pw"]["bn"], conv2d(x, blk["pw"]["w"])))
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["fc"]["w"] + p["fc"]["b"]


# ---------------------------------------------------------------------------
# Registry + cycle-model layer shapes (full-size models, Fig. 10 inputs)
# ---------------------------------------------------------------------------

CNN_ZOO: Dict[str, Tuple[Callable, Callable]] = {
    "vgg16": (init_vgg16, apply_vgg16),
    "resnet56": (init_resnet56, apply_resnet56),
    "mobilenetv2": (init_mobilenetv2, apply_mobilenetv2),
    "dscnn": (init_dscnn, apply_dscnn),
}


def _conv_shape(kh, kw, cin, cout, oh, ow) -> LayerShape:
    return LayerShape("conv", (kh, kw, _pad4(cin), cout), (oh, ow))


def _dw_shape(kh, kw, c, oh, ow) -> LayerShape:
    """Depthwise conv as per-channel tap streams (taps padded to ×4)."""
    return LayerShape("conv", (1, 1, _pad4(kh * kw), c), (oh, ow))


def layer_shapes(model: str) -> List[LayerShape]:
    """MAC-bearing layers of the full-size paper models (input resolutions:
    CIFAR 32², VWW 96², GSC 49×10)."""
    if model == "vgg16":
        out, c, hw = [], 3, 32
        for v in VGG16_PLAN:
            if v == "M":
                hw //= 2
            else:
                out.append(_conv_shape(3, 3, c, v, hw, hw))
                c = v
        out.append(LayerShape("linear", (_pad4(c), 10)))
        return out
    if model == "resnet56":
        out, cin, hw = [_conv_shape(3, 3, 3, 16, 32, 32)], 16, 32
        for s, cout in enumerate((16, 32, 64)):
            for b in range(9):
                stride = 2 if (s > 0 and b == 0) else 1
                hw = hw // stride
                out.append(_conv_shape(3, 3, cin, cout, hw, hw))
                out.append(_conv_shape(3, 3, cout, cout, hw, hw))
                if stride != 1 or cin != cout:
                    out.append(_conv_shape(1, 1, cin, cout, hw, hw))
                cin = cout
        out.append(LayerShape("linear", (64, 10)))
        return out
    if model == "mobilenetv2":
        out, cin, hw = [_conv_shape(3, 3, 3, 32, 48, 48)], 32, 48
        for t, c, n, s in MBV2_PLAN:
            for i in range(n):
                stride = s if i == 0 else 1
                hidden = cin * t
                if t != 1:
                    out.append(_conv_shape(1, 1, cin, hidden, hw, hw))
                hw = hw // stride
                out.append(_dw_shape(3, 3, hidden, hw, hw))
                out.append(_conv_shape(1, 1, hidden, c, hw, hw))
                cin = c
        out.append(_conv_shape(1, 1, cin, 1280, hw, hw))
        out.append(LayerShape("linear", (1280, 2)))
        return out
    if model == "dscnn":
        out = [_conv_shape(10, 4, 1, 64, 25, 5)]
        for _ in range(4):
            out.append(_dw_shape(3, 3, 64, 25, 5))
            out.append(_conv_shape(1, 1, 64, 64, 25, 5))
        out.append(LayerShape("linear", (64, 12)))
        return out
    raise ValueError(f"unknown model {model!r}; one of {list(CNN_ZOO)}")


# ---------------------------------------------------------------------------
# Offline weight transforms (prune / quantize) over a CNN params pytree
# ---------------------------------------------------------------------------

def _is_weight(path: Tuple, leaf: Array) -> bool:
    """Conv/fc kernels only (≥2D float leaves named 'w*')."""
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    last = str(names[-1]) if names else ""
    return leaf.ndim >= 2 and last.startswith("w")


def map_weights(params: Params, fn: Callable[[Array], Array]) -> Params:
    """Apply ``fn`` to every conv/fc kernel leaf; leave norms/bias alone."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(leaf) if _is_weight(path, leaf) else leaf,
        params)


def quantize_dequantize(params: Params, bits7: bool) -> Params:
    """Fake-quantize weights through INT8 or INT7 (Table II comparison)."""
    from repro.core import encoding

    def qdq(w: Array) -> Array:
        flat = w.reshape(-1, w.shape[-1])
        if bits7:
            q, scale = encoding.quantize_int7(flat, axis=0)
        else:
            q, scale = encoding.quantize_int8(flat, axis=0)
        return (q.astype(jnp.float32) * scale).reshape(w.shape)

    return map_weights(params, qdq)
