"""GQA attention with KV cache — every attention variant the assigned
architectures need, behind one init/apply pair.

Variants (config-driven): grouped-query (any H/Hk ratio incl. MQA), qk-norm
(qwen3), sliding windows (gemma local layers), logit soft-capping (gemma2),
M-RoPE (qwen2-vl), cross-attention (seamless decoder).  The inner product
uses the inline chunked-flash jnp path below (SPMD-partitionable, cache-
aware; ``kernels.dispatch.attention`` provides the Pallas flash kernel for
standalone prefill shapes); projections dispatch through ``apply_linear``
→ ``kernels.dispatch`` so the paper's sparse formats apply to q/k/v/o like
any other matmul.

KV cache layouts (per layer):
  * monolithic — ``{"k": (B, S, Hk, D), "v": (B, S, Hk, D)}``,
    sequence-major so decode updates are one ``dynamic_update_slice`` and
    the "kv_seq" axis can be sharded for long contexts (DESIGN.md §6).
  * paged — ``{"kp": (P, ps, Hk, D), "vp": (P, ps, Hk, D),
    "ptab": (B, max_pages) int32}``: a shared page *pool* plus a per-slot
    page table mapping logical page ``j`` of slot ``b`` (rows
    ``[j*ps, (j+1)*ps)``) to a pool page.  Page 0 is the reserved null
    page: unallocated table entries point at it, writes from dead slots
    land in it, and the kv-length mask keeps reads from ever attending to
    it.  This is the memory-side analogue of the paper's metadata-driven
    skipping — the page table is the few bits of indirection metadata
    that let cache memory and attention work scale with *actual* sequence
    lengths instead of the padded maximum.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sparse_linear import DENSE, SparsityConfig, apply_linear, \
    init_linear
from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array
Params = Dict[str, Any]


def init_attention(rng: Array, cfg: ModelConfig, d_in: Optional[int] = None,
                   dtype=jnp.bfloat16) -> Params:
    d = d_in if d_in is not None else cfg.d_model
    ks = jax.random.split(rng, 4)
    p = {
        "wq": init_linear(ks[0], d, cfg.q_dim, dtype),
        "wk": init_linear(ks[1], d, cfg.kv_dim, dtype),
        "wv": init_linear(ks[2], d, cfg.kv_dim, dtype),
        "wo": init_linear(ks[3], cfg.q_dim, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.init_rmsnorm(cfg.head_dim)
        p["k_norm"] = L.init_rmsnorm(cfg.head_dim)
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None, dtype=jnp.bfloat16) -> Params:
    """Stacked-over-layers cache (leading L axis matches the layer scan)."""
    nl = n_layers if n_layers is not None else cfg.n_layers
    shape = (nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_max_pages(max_len: int, page_size: int) -> int:
    """Logical pages per slot covering a ``max_len`` sequence."""
    return -(-max_len // page_size)


def init_paged_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                        page_size: int, num_pages: int = 0,
                        n_layers: Optional[int] = None,
                        dtype=jnp.bfloat16) -> Params:
    """Paged cache: shared page pool + per-slot page table.

    ``num_pages`` counts *allocatable* pages; one extra null page (pool
    index 0) is always added, so the pool leaf is ``num_pages + 1`` pages
    deep.  ``num_pages=0`` sizes the pool at full capacity
    (``batch * max_pages`` — no memory win, but bit-identical serving for
    parity tests).  The page table starts all-null.
    """
    nl = n_layers if n_layers is not None else cfg.n_layers
    mp = paged_max_pages(max_len, page_size)
    if num_pages <= 0:
        num_pages = batch * mp
    pool = (nl, num_pages + 1, page_size, cfg.n_kv_heads, cfg.head_dim)
    return {"kp": jnp.zeros(pool, dtype), "vp": jnp.zeros(pool, dtype),
            "ptab": jnp.zeros((nl, batch, mp), jnp.int32)}


def _project_qkv(params: Params, cfg: ModelConfig, x: Array,
                 kv_src: Optional[Array] = None,
                 sparsity: SparsityConfig = DENSE):
    """x (B, L, d) → q (B, L, H, D), k/v (B, Lk, Hk, D)."""
    B, Lq, _ = x.shape
    src = x if kv_src is None else kv_src
    Lk = src.shape[1]
    q = apply_linear(x, params["wq"], sparsity)
    k = apply_linear(src, params["wk"], sparsity)
    v = apply_linear(src, params["wv"], sparsity)
    q = q.reshape(B, Lq, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, Lk, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Lk, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _mask(cfg: ModelConfig, qpos: Array, kpos: Array, *, causal: bool,
          window: Optional[int], is_local, kv_len) -> Array:
    """(..., Lq, Lk) bool reachability mask.  ``is_local`` may be a *traced*
    bool (scanned heterogeneous local/global stacks select the window mask
    at run time — both masks are elementwise-cheap).

    ``qpos`` is (Lq,) or (B, Lq) and ``kv_len`` None / scalar / (B,) —
    the batched forms carry per-slot decode positions (continuous
    batching), broadcasting a leading batch axis onto the mask.
    """
    q = qpos[..., :, None]                       # (..., Lq, 1)
    mask = jnp.ones(q.shape[:-1] + (kpos.shape[0],), bool)
    if causal:
        mask &= kpos <= q
    if window is not None:
        wmask = kpos > q - window
        if isinstance(is_local, bool):
            if is_local:
                mask &= wmask
        else:
            mask &= wmask | ~is_local
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        if kvl.ndim == 1:                        # per-slot valid lengths
            kvl = kvl[:, None, None]
        mask &= kpos < kvl
    return mask


def _expand_mask(mask: Array) -> Array:
    """Broadcast a (Lq, Lk) or (B, Lq, Lk) mask onto (B, Hk, g, Lq, Lk)."""
    if mask.ndim == 2:
        return mask[None, None, None]
    return mask[:, None, None]


def _sdpa(cfg: ModelConfig, q: Array, k: Array, v: Array, *,
          causal: bool, window: Optional[int], is_local=True,
          kv_len: Optional[Array] = None) -> Array:
    """(B, Lq, H, D) × (B, Lk, Hk, D) → (B, Lq, H, D).

    jnp path (XLA SPMD-partitionable; what the dry-run lowers).  ``kv_len``
    masks cache positions ≥ the valid length during decode.  For long keys
    the computation is chunked over Lk (flash-style online softmax in a
    ``lax.scan``) so the (Lq, Lk) logits are never materialized whole.
    """
    B, Lq, H, D = q.shape
    Lk, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    qh = q.transpose(0, 2, 1, 3).reshape(B, Hk, g, Lq, D).astype(jnp.float32)
    kh = k.transpose(0, 2, 1, 3).astype(jnp.float32)   # (B, Hk, Lk, D)
    vh = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        # abs position of queries; (Lq,) for scalar kv_len, (B, Lq) when
        # kv_len is per-slot (vector cache_pos decode)
        qpos = (kvl[:, None] if kvl.ndim == 1 else kvl) - Lq + jnp.arange(Lq)
    else:
        qpos = jnp.arange(Lq) + (Lk - Lq)
    scale = D ** -0.5

    chunk = _KV_CHUNK
    if Lk <= chunk or Lk % chunk:
        kpos = jnp.arange(Lk)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qh, kh) * scale
        if cfg.attn_softcap is not None:
            logits = jnp.tanh(logits / cfg.attn_softcap) * cfg.attn_softcap
        mask = _expand_mask(_mask(cfg, qpos, kpos, causal=causal,
                                  window=window, is_local=is_local,
                                  kv_len=kv_len))
        logits = jnp.where(mask, logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vh)
    else:
        # double-chunked flash: outer map over Q chunks, inner online-
        # softmax scan over KV chunks — peak logits buffer is
        # (B, Hk, g, qc, chunk) regardless of sequence length, which is
        # what lets the 32k-prefill and 500k-decode cells fit HBM.
        qc = Lq if (Lq <= _Q_CHUNK or Lq % _Q_CHUNK) else _Q_CHUNK
        nq = Lq // qc
        nc = Lk // chunk
        kc_ = kh.reshape(B, Hk, nc, chunk, D).transpose(2, 0, 1, 3, 4)
        vc_ = vh.reshape(B, Hk, nc, chunk, D).transpose(2, 0, 1, 3, 4)
        qcs = qh.reshape(B, Hk, g, nq, qc, D).transpose(3, 0, 1, 2, 4, 5)
        if qpos.ndim == 2:          # per-slot positions: (B, Lq) → (nq, B, qc)
            qpos_c = qpos.reshape(B, nq, qc).transpose(1, 0, 2)
        else:
            qpos_c = qpos.reshape(nq, qc)

        def q_block(args):
            qb, qp = args                       # (B,Hk,g,qc,D), (qc,)

            def step(carry, xs):
                m_run, l_run, acc = carry
                kb, vb, ci = xs
                kpos = ci * chunk + jnp.arange(chunk)
                s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
                if cfg.attn_softcap is not None:
                    s = jnp.tanh(s / cfg.attn_softcap) * cfg.attn_softcap
                mask = _expand_mask(
                    _mask(cfg, qp, kpos, causal=causal, window=window,
                          is_local=is_local, kv_len=kv_len))
                s = jnp.where(mask, s, -1e30)
                m_new = jnp.maximum(m_run,
                                    jnp.max(s, axis=-1, keepdims=True))
                p = jnp.exp(s - m_new)
                p = jnp.where(mask, p, 0.0)
                alpha = jnp.exp(m_run - m_new)
                l_new = l_run * alpha + jnp.sum(p, -1, keepdims=True)
                acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
                return (m_new, l_new, acc), None

            init = (jnp.full((B, Hk, g, qc, 1), -1e30, jnp.float32),
                    jnp.zeros((B, Hk, g, qc, 1), jnp.float32),
                    jnp.zeros((B, Hk, g, qc, D), jnp.float32))
            (m_run, l_run, acc), _ = jax.lax.scan(
                step, init, (kc_, vc_, jnp.arange(nc)))
            return acc / jnp.where(l_run == 0.0, 1.0, l_run)

        if nq == 1:
            out = q_block((qcs[0], qpos_c[0]))                  # (B,Hk,g,Lq,D)
        else:
            out = jax.lax.map(q_block, (qcs, qpos_c))           # (nq,B,Hk,g,qc,D)
            out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hk, g, Lq, D)
    return out.reshape(B, H, Lq, D).transpose(0, 2, 1, 3).astype(q.dtype)


# chunk sizes for the lax.scan flash path; full-logit path below KV chunk.
_KV_CHUNK = 2048
_Q_CHUNK = 2048


def attention(params: Params, cfg: ModelConfig, x: Array, positions: Array,
              *, is_local=False,
              cache: Optional[Params] = None,
              cache_pos: Optional[Array] = None,
              cross_src: Optional[Array] = None,
              causal: Optional[bool] = None,
              sparsity: SparsityConfig = DENSE
              ) -> Tuple[Array, Optional[Params]]:
    """Full attention layer: project → rope → (cache update) → sdpa → out.

    Modes:
      * prefill / training: ``cache=None`` → self-attention over ``x``.
      * decode: ``cache`` holds (B, S, Hk, D) k/v for THIS layer and
        ``cache_pos`` (scalar) the write position; returns updated cache.
        A paged layer cache (``{"kp", "vp", "ptab"}``, see module
        docstring) is detected by its ``ptab`` key and routed through the
        page-table scatter/gather instead.
      * cross-attention: ``cross_src`` is the encoder output (no rope on kv,
        no causal mask).
      * ``causal=False`` with ``cross_src=None``: bidirectional
        self-attention (encoder stacks).

    ``is_local`` may be a traced bool (scanned local/global stacks).
    """
    window = cfg.window_size
    if causal is None:
        causal = cross_src is None
    q, k, v = _project_qkv(params, cfg, x, cross_src, sparsity)

    if cross_src is None:
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        kv_pos = positions
        k = L.apply_rope(k, kv_pos, cfg.rope_theta, cfg.mrope_sections)

    # Attention head/sequence layout (TP mode): head-parallel flash when
    # the KV heads divide the model axis (zero intra-attention
    # collectives); for MQA (Hk ≤ 2, e.g. gemma3) replicate the — tiny by
    # design — KV and sequence-shard the queries.  Without this, GSPMD
    # partitions the QK^T contraction over head_dim and emits per-chunk
    # partial-sum all-reduces (measured 200 GB/step on gemma3
    # prefill_32k, §Perf cell C).
    from repro.distributed.annotate import (MODEL, axis_size, batch_axes,
                                            constrain, seq_axis)
    ext = axis_size(MODEL)
    if seq_axis() is not None and ext > 1:
        if cfg.n_kv_heads % ext == 0:
            q = constrain(q, batch_axes(), None, MODEL, None)
            k = constrain(k, batch_axes(), None, MODEL, None)
            v = constrain(v, batch_axes(), None, MODEL, None)
        elif cfg.n_kv_heads <= 2:
            q = constrain(q, batch_axes(), MODEL, None, None)
            k = constrain(k, batch_axes(), None, None, None)
            v = constrain(v, batch_axes(), None, None, None)

    new_cache = None
    kv_len = None
    if cache is not None and "ptab" in cache:
        # paged cache: scatter the new rows into the pool pages named by
        # the slot's page table, then gather the table back as a
        # (B, max_pages*ps, Hk, D) logical view.  Row index == logical
        # position, so the downstream mask/qpos math is unchanged; rows
        # past kv_len read whatever the mapped page holds (null-page
        # garbage included) and are masked exactly like monolithic
        # garbage rows.  Prefix sharing may map ONE page into SEVERAL
        # table rows: safe by the same math — gathers tolerate duplicate
        # page ids, and each slot's scatter lands at its own positions
        # (≥ its prompt rows), which always resolve to slot-private
        # pages, so a shared page is only ever read.
        pt = cache["ptab"]                          # (B, max_pages)
        ps = cache["kp"].shape[1]
        B, Lq = x.shape[0], x.shape[1]
        cp = jnp.asarray(cache_pos)
        cpb = cp if cp.ndim == 1 else jnp.broadcast_to(cp, (B,))
        posn = cpb[:, None] + jnp.arange(Lq)[None, :]           # (B, Lq)
        pages = jnp.take_along_axis(
            pt, jnp.clip(posn // ps, 0, pt.shape[1] - 1), axis=1)
        offs = posn % ps
        ck = cache["kp"].at[pages, offs].set(k.astype(cache["kp"].dtype))
        cv = cache["vp"].at[pages, offs].set(v.astype(cache["vp"].dtype))
        new_cache = {"kp": ck, "vp": cv, "ptab": pt}
        Hk, D = k.shape[-2], k.shape[-1]
        k = ck[pt].reshape(B, -1, Hk, D)
        v = cv[pt].reshape(B, -1, Hk, D)
        if ext > 1 and Hk % ext == 0:
            # head-parallel pool (cache_specs "heads"): keep the gathered
            # view sharded on its head axis so the page gather stays
            # shard-local and attention runs collective-free per head
            k = constrain(k, batch_axes(), None, MODEL, None)
            v = constrain(v, batch_axes(), None, MODEL, None)
        kv_len = cpb + Lq
    elif cache is not None:
        # write the new k/v at cache_pos, attend over the whole cache.
        # cache_pos may be a scalar (shared write offset: prefill, wave
        # decode) or a (B,) vector of per-slot positions (continuous
        # batching: each slot advances independently).
        cp = jnp.asarray(cache_pos)
        if cp.ndim == 1:
            def _upd(c, n, p):          # (S, Hk, D), (Lq, Hk, D), ()
                return jax.lax.dynamic_update_slice(c, n, (p, 0, 0))
            ck = jax.vmap(_upd)(cache["k"], k.astype(cache["k"].dtype), cp)
            cv = jax.vmap(_upd)(cache["v"], v.astype(cache["v"].dtype), cp)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        kv_len = cp + x.shape[1]

    out = _sdpa(cfg, q, k, v, causal=causal, window=window,
                is_local=is_local, kv_len=kv_len)
    B, Lq = x.shape[0], x.shape[1]
    out = out.reshape(B, Lq, cfg.q_dim)
    out = apply_linear(out, params["wo"], sparsity)
    return out, new_cache
