"""Hybrid / SSM LM assemblies: zamba2 (Mamba-2 backbone + *shared*
attention block) and pure mamba2.

zamba2 (arXiv:2411.15242) runs a Mamba-2 backbone and applies one globally
*shared* transformer block (attention + MLP, one set of weights) every few
layers — parameter-cheap global mixing over an attention-free trunk.  We
implement the shared block faithfully as shared weights; the paper's
per-invocation LoRA deltas are omitted (noted in DESIGN.md §model-notes) —
they are a parameter-efficiency refinement orthogonal to the systems
contribution here.

Layer pattern comes from ``cfg.layer_kinds``: ``MAMBA`` layers form the
trunk; a ``SHARED_ATTN`` entry means "apply the shared attention block,
then this (mamba) layer".  Pure mamba2 is the special case with no
``SHARED_ATTN`` entries.

Scan structure: mamba layers are stacked and scanned in *runs* between
shared-block applications (run boundaries are static), so compile time is
O(#runs) and the KV cache exists only for the handful of shared slots —
at 500k context this is what makes the long-context decode cell fit:
SSM state is O(1) in L and KV memory is ``n_shared_slots``-fold, not
``n_layers``-fold.

Sparsity: the Mamba in/out projections (≈85% of trunk params) and the
shared block's projections dispatch through ``apply_linear`` — the paper's
formats apply to every weight matmul; the SSD recurrence itself has no
weight matmul to sparsify (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import attention, init_attention
from repro.models.config import LayerKind, ModelConfig
from repro.models.ssm import init_mamba, init_ssm_cache, mamba_block

Array = jax.Array
Params = Dict[str, Any]


def shared_slots(cfg: ModelConfig) -> List[int]:
    """Layer indices where the shared attention block fires (before the
    mamba layer at that index)."""
    return [i for i, k in enumerate(cfg.layer_kinds)
            if LayerKind(k) == LayerKind.SHARED_ATTN]


def _runs(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """Static (lo, hi) mamba-layer runs between shared-block applications."""
    slots = shared_slots(cfg)
    bounds = [0] + slots + [cfg.n_layers]
    return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)
            if bounds[i] < bounds[i + 1]]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_hybrid_lm(rng: Array, cfg: ModelConfig) -> Params:
    dtype = L._dtype(cfg.dtype)
    k_embed, k_trunk, k_shared = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_trunk, cfg.n_layers)

    def one_layer(k):
        p = init_mamba(k, cfg, dtype=dtype)
        p["ln"] = L.init_rmsnorm(cfg.d_model)
        return p

    p: Params = {
        "embed": L.init_embedding(k_embed, cfg.vocab_padded, cfg.d_model,
                                  dtype),
        "mamba": jax.vmap(one_layer)(layer_keys),
        "ln_final": L.init_rmsnorm(cfg.d_model),
    }
    if shared_slots(cfg):
        ks = jax.random.split(k_shared, 2)
        p["shared"] = {
            "ln_attn": L.init_rmsnorm(cfg.d_model),
            "attn": init_attention(ks[0], cfg, dtype=dtype),
            "ln_mlp": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                              gated=cfg.mlp_gated, dtype=dtype),
        }
    if not cfg.tie_embeddings:
        p["unembed"] = L.init_embedding(
            jax.random.fold_in(k_embed, 1), cfg.vocab_padded, cfg.d_model,
            dtype)
    return p


def init_hybrid_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, page_size: int = 0,
                      num_pages: int = 0) -> Params:
    """{"ssm": stacked(n_layers) conv+state, "kv": (n_shared, B, S, Hk, D)}.

    KV exists only for the shared slots — the memory shape that makes
    500k-context decode feasible for this family.  ``page_size > 0``
    makes the shared-attention KV paged (pool + page table, see
    ``attention.init_paged_kv_cache``); the SSM state is O(1) in sequence
    length and has nothing to page.
    """
    from repro.models.attention import init_paged_kv_cache
    cache: Params = {"ssm": init_ssm_cache(cfg, batch)}
    n_shared = len(shared_slots(cfg))
    if n_shared:
        if page_size:
            cache["kv"] = init_paged_kv_cache(
                cfg, batch, max_len, page_size, num_pages,
                n_layers=n_shared, dtype=dtype)
        else:
            shape = (n_shared, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            cache["kv"] = {"k": jnp.zeros(shape, dtype),
                           "v": jnp.zeros(shape, dtype)}
    return cache


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def _slice_tree(tree, lo: int, hi: int):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def _scan_run(params_run: Params, cfg: ModelConfig, x: Array,
              cache_run: Optional[Params], remat: bool,
              collect_states: bool = False
              ) -> Tuple[Array, Optional[Params]]:
    """lax.scan over one contiguous run of mamba layers."""

    def body(x, xs):
        p_layer, cache_layer = xs
        h = L.rmsnorm(p_layer["ln"], x, cfg.norm_eps)
        out, new_cache = mamba_block(p_layer, cfg, h, cache=cache_layer,
                                     sparsity=cfg.mlp_sparsity,
                                     collect_states=collect_states)
        return x + out, new_cache

    body_fn = jax.checkpoint(body) if remat else body
    return jax.lax.scan(body_fn, x, (params_run, cache_run))


def hybrid_apply(params: Params, cfg: ModelConfig, inputs: Array,
                 positions: Optional[Array] = None,
                 cache: Optional[Params] = None,
                 cache_pos=None, last_only: bool = False,
                 collect_states: bool = False
                 ) -> Tuple[Array, Optional[Params], Array]:
    """Tokens → logits for mamba2/zamba2.  Same contract as ``lm_apply``."""
    x, new_cache = hybrid_hidden(params, cfg, inputs, positions, cache,
                                 cache_pos, collect_states=collect_states)
    if last_only:
        x = x[:, -1:]
    table = params.get("unembed", params["embed"])
    logits = L.unembed(table, x, softcap=cfg.final_softcap)
    return logits, new_cache, jnp.zeros((), jnp.float32)


def hybrid_hidden(params: Params, cfg: ModelConfig, inputs: Array,
                  positions: Optional[Array] = None,
                  cache: Optional[Params] = None,
                  cache_pos=None,
                  collect_states: bool = False
                  ) -> Tuple[Array, Optional[Params]]:
    """The shared trunk: tokens → final (normed) hidden states.

    ``collect_states=True`` (multi-token verify): every mamba layer also
    emits per-position recurrent-state snapshots, returned inside
    ``new_cache["ssm"]`` as ``"conv_seq"`` / ``"ssm_seq"`` leaves (see
    :func:`repro.models.ssm.mamba_block`); ``hybrid_decode_block`` splits
    them back out.
    """
    B, Lq = inputs.shape[:2]
    x = L.embed(params["embed"], inputs, scale=cfg.embed_scale)
    if positions is None:
        base = jnp.arange(Lq)
        if cache_pos is not None:
            cp = jnp.asarray(cache_pos)
            # scalar offset (shared) or (B,) per-slot decode positions
            base = base[None, :] + (cp[:, None] if cp.ndim == 1 else cp)
        positions = jnp.broadcast_to(base, (B, Lq))

    remat = cfg.remat and cache is None
    runs = _runs(cfg)
    slots = shared_slots(cfg)
    ssm_cache = cache["ssm"] if cache is not None else None
    kv_cache = cache.get("kv") if cache is not None else None

    new_ssm: list = []
    new_kv: list = []
    for r, (lo, hi) in enumerate(runs):
        # shared attention block before this run (except before run 0
        # unless layer 0 is itself a shared slot)
        if lo in slots:
            s = slots.index(lo)
            sp = params["shared"]
            h = L.rmsnorm(sp["ln_attn"], x, cfg.norm_eps)
            # per-slot layer cache: monolithic {"k","v"} or paged
            # {"kp","vp","ptab"} — every leaf is stacked over shared slots
            layer_kv = (None if kv_cache is None else
                        {name: leaf[s] for name, leaf in kv_cache.items()})
            attn_out, new_layer_kv = attention(
                sp["attn"], cfg, h, positions,
                cache=layer_kv, cache_pos=cache_pos,
                sparsity=cfg.attn_sparsity)
            x = x + attn_out
            h = L.rmsnorm(sp["ln_mlp"], x, cfg.norm_eps)
            x = x + L.mlp(sp["mlp"], h, gated=cfg.mlp_gated,
                          sparsity=cfg.mlp_sparsity)
            if new_layer_kv is not None:
                new_kv.append(new_layer_kv)
        run_cache = (None if ssm_cache is None
                     else _slice_tree(ssm_cache, lo, hi))
        x, run_new_cache = _scan_run(
            _slice_tree(params["mamba"], lo, hi), cfg, x, run_cache, remat,
            collect_states=collect_states)
        if run_new_cache is not None and ssm_cache is not None:
            new_ssm.append(run_new_cache)

    new_cache = None
    if cache is not None:
        new_cache = {"ssm": jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm)}
        if kv_cache is not None:
            new_cache["kv"] = {name: jnp.stack([kv[name] for kv in new_kv])
                               for name in new_kv[0]}

    x = L.rmsnorm(params["ln_final"], x, cfg.norm_eps)
    return x, new_cache


def hybrid_loss(params: Params, cfg: ModelConfig, tokens: Array,
                labels: Array) -> Array:
    """Mean next-token CE via the vocab-chunked logsumexp (no logits
    tensor — see transformer.chunked_ce)."""
    from repro.models.transformer import chunked_ce
    x, _ = hybrid_hidden(params, cfg, tokens)
    table = params.get("unembed", params["embed"])
    return chunked_ce(x, table, labels, cfg)


def hybrid_prefill(params: Params, cfg: ModelConfig, inputs: Array,
                   cache: Params) -> Tuple[Array, Params]:
    logits, new_cache, _ = hybrid_apply(
        params, cfg, inputs, cache=cache, cache_pos=jnp.zeros((), jnp.int32),
        last_only=True)
    return logits[:, -1], new_cache


def hybrid_decode_step(params: Params, cfg: ModelConfig, token: Array,
                       cache: Params, pos: Array) -> Tuple[Array, Params]:
    logits, new_cache, _ = hybrid_apply(
        params, cfg, token[:, None], cache=cache, cache_pos=pos)
    return logits[:, 0], new_cache


def hybrid_decode_block(params: Params, cfg: ModelConfig, tokens: Array,
                        cache: Params, pos: Array, collect: bool = False
                        ) -> Tuple[Array, Params, Optional[Params]]:
    """Multi-token decode-shaped forward (the speculative verify step):
    ``tokens (B, T)`` at per-slot positions ``pos (B,)`` → logits
    ``(B, T, vocab_padded)`` + updated cache.

    ``collect=True`` additionally returns per-position recurrent-state
    snapshots ``{"conv": (nl, B, T, K-1, c), "ssm": (nl, B, T, h, p, n)}``
    — the state *after* each block position — so the caller can roll the
    recurrence back to any accepted prefix (KV rolls back by position
    masking; SSM state by snapshot selection)."""
    logits, new_cache, _ = hybrid_apply(
        params, cfg, tokens, cache=cache, cache_pos=pos,
        collect_states=collect)
    snaps = None
    if collect and new_cache is not None:
        ssm = dict(new_cache["ssm"])
        snaps = {"conv": ssm.pop("conv_seq"), "ssm": ssm.pop("ssm_seq")}
        new_cache = {**new_cache, "ssm": ssm}
    return logits, new_cache, snaps
