"""Mamba-2 (SSD — state-space duality) block, chunked, pure JAX.

Implements the SSD algorithm of arXiv:2405.21060 (the minimal discrete
formulation): within chunks of length Q the recurrence is materialized as a
(Q, Q) lower-triangular attention-like matmul (MXU-friendly); across chunks
a linear recurrence over per-chunk states runs as an O(L/Q) scan.  Decode
is the O(1) recurrent update.  The block's big matmuls — ``in_proj`` and
``out_proj``, ≈85% of parameters — dispatch through ``apply_linear`` so the
paper's sparse formats apply (DESIGN.md §Arch-applicability: the SSD state
update itself is elementwise/scan, no weight matmul to sparsify).

Shapes: d_inner = expand·d_model, H heads of dim P = d_inner/H, state N,
B/C shared across G groups (we materialize per-head for clarity; G=1 for
both assigned SSM archs).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sparse_linear import DENSE, SparsityConfig, apply_linear, \
    init_linear
from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array
Params = Dict[str, Any]


def init_mamba(rng: Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    K = cfg.ssm_conv
    conv_dim = di + 2 * G * N
    ks = jax.random.split(rng, 4)
    return {
        "in_proj": init_linear(ks[0], d, 2 * di + 2 * G * N + H, dtype),
        "out_proj": init_linear(ks[1], di, d, dtype),
        "conv_w": (jax.random.normal(ks[2], (K, conv_dim), jnp.float32)
                   / jnp.sqrt(K)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": L.init_rmsnorm(di),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int,
                   n_layers: Optional[int] = None, dtype=jnp.float32) -> Params:
    """Stacked per-layer recurrent state: O(1) in sequence length."""
    nl = n_layers if n_layers is not None else cfg.n_layers
    di = cfg.d_inner
    G, N, H, P = cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads, \
        cfg.ssm_head_dim
    conv_dim = di + 2 * G * N
    return {
        "conv": jnp.zeros((nl, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((nl, batch, H, P, N), dtype),
    }


def _segsum(a: Array) -> Array:
    """(..., Q) → (..., Q, Q): S[i, j] = Σ_{k=j+1..i} a[k] (−inf above diag)."""
    c = jnp.cumsum(a, axis=-1)
    S = c[..., :, None] - c[..., None, :]
    Q = a.shape[-1]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(tri, S, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                chunk: int, init_state: Optional[Array] = None,
                return_states: bool = False):
    """SSD scan.  x (b,l,h,p), dt (b,l,h), A (h,), B/C (b,l,h,n) →
    (y (b,l,h,p), final_state (b,h,p,n)).

    ``return_states=True`` forces ``chunk=1`` (the inter-chunk recurrence
    then runs per position) and additionally returns the recurrent state
    *after every position* as (b, l, h, p, n) — what speculative decoding
    needs to roll the state back to an arbitrary accepted prefix.
    """
    if return_states:
        chunk = 1
    b, l, h, p = x.shape
    n = B.shape[-1]
    nc = l // chunk
    assert l % chunk == 0, f"L={l} not divisible by chunk={chunk}"

    xd = (x * dt[..., None]).astype(jnp.float32)          # discretized input
    a = (dt * A).astype(jnp.float32)                      # (b, l, h) decay logs

    # → chunk layout
    xd = xd.reshape(b, nc, chunk, h, p)
    Bc = B.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    ac = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)  # (b, h, nc, Q)
    a_cum = jnp.cumsum(ac, axis=-1)                        # (b, h, nc, Q)

    # 1. intra-chunk (diagonal blocks): quadratic in Q, MXU-shaped
    Lmat = jnp.exp(_segsum(ac))                            # (b, h, nc, Q, Q)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        Cc, Bc, Lmat, xd)

    # 2. per-chunk final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)        # (b, h, nc, Q)
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn", Bc, decay_states, xd)

    # 3. inter-chunk recurrence (includes the initial state slot)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    states = jnp.concatenate([init_state[:, None].astype(jnp.float32),
                              states], axis=1)             # (b, nc+1, h, p, n)
    chunk_sum = a_cum[..., -1]                             # (b, h, nc)
    padded = jnp.pad(chunk_sum, ((0, 0), (0, 0), (1, 0)))  # (b, h, nc+1)
    decay_chunk = jnp.exp(_segsum(padded))                 # (b,h,nc+1,nc+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    states_in, final = new_states[:, :-1], new_states[:, -1]

    # 4. inter-chunk contribution to outputs
    out_decay = jnp.exp(a_cum)                             # (b, h, nc, Q)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Cc, states_in, out_decay)

    y = (y_diag + y_off).reshape(b, l, h, p)
    if return_states:
        # state AFTER position t = state before position t+1; the last
        # one is the final state (chunk == 1 → one position per chunk)
        after = jnp.concatenate([states_in[:, 1:], final[:, None]], axis=1)
        return y, final, after
    return y, final


def _causal_conv(xBC: Array, w: Array, bias: Array,
                 state: Optional[Array] = None) -> Tuple[Array, Array]:
    """Depthwise causal conv along L.  xBC (b, l, c), w (K, c) →
    (out (b, l, c), new_state (b, K-1, c))."""
    K = w.shape[0]
    pad = state if state is not None else \
        jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    xp = jnp.concatenate([pad.astype(xBC.dtype), xBC], axis=1)
    out = sum(xp[:, k:k + xBC.shape[1], :] * w[k][None, None, :]
              for k in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else pad
    return out + bias[None, None, :], new_state


def _pick_chunk(l: int, target: int) -> int:
    """Largest divisor of ``l`` that is ≤ target (SSD chunk length)."""
    c = min(target, l)
    while l % c:
        c -= 1
    return c


def mamba_block(params: Params, cfg: ModelConfig, x: Array, *,
                cache: Optional[Params] = None,
                sparsity: SparsityConfig = DENSE,
                collect_states: bool = False
                ) -> Tuple[Array, Optional[Params]]:
    """One Mamba-2 mixer.  ``cache`` (decode): {"conv": (b,K-1,c),
    "ssm": (b,h,p,n)} → returns updated cache; None → chunked scan.

    ``collect_states=True`` (multi-token verify path, needs ``cache`` and
    ``l > 1``): the returned cache additionally carries per-position
    snapshots — ``"conv_seq"`` (b, l, K-1, c) and ``"ssm_seq"``
    (b, l, h, p, n), the recurrent state *after* each of the l positions —
    so a speculative-decode caller can truncate the recurrence to any
    accepted prefix (KV caches roll back by masking; recurrent state
    rolls back by selecting the snapshot).
    """
    b, l, d = x.shape
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_head_dim

    zxbcdt = apply_linear(x, params["in_proj"], sparsity)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])   # (b, l, H)

    conv_state = cache["conv"] if cache is not None else None
    xBC_raw = xBC                       # pre-conv inputs (conv-state domain)
    xBC, new_conv = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                                 conv_state)
    if conv_state is not None:
        # keep the cache's dtype: the serving decode loop carries the
        # cache through a lax.scan, whose carry type must be stable
        new_conv = new_conv.astype(conv_state.dtype)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs, B, C = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = xs.reshape(b, l, H, P)
    # expand B/C groups to heads
    rep = H // G
    B = jnp.repeat(B.reshape(b, l, G, N), rep, axis=2)
    C = jnp.repeat(C.reshape(b, l, G, N), rep, axis=2)
    A = -jnp.exp(params["A_log"])                              # (H,)

    if cache is None:
        y, _ = ssd_chunked(xs, dt, A, B, C, _pick_chunk(l, cfg.ssm_chunk))
        new_cache = None
    elif l > 1:
        # prefill with cache: chunked scan seeded from the cached state
        if collect_states:
            y, final, ssm_seq = ssd_chunked(
                xs, dt, A, B, C, 1,
                init_state=cache["ssm"].astype(jnp.float32),
                return_states=True)
            # conv state after position t = the last K-1 pre-conv inputs
            # of the prefix ending at t: sliding windows over the padded
            # input buffer (this branch requires a cache, so conv_state
            # is always set)
            Kc = params["conv_w"].shape[0]
            xp = jnp.concatenate(
                [conv_state.astype(xBC_raw.dtype), xBC_raw], 1)
            win = (jnp.arange(l)[:, None] + 1 + jnp.arange(Kc - 1)[None, :])
            conv_seq = xp[:, win].astype(new_conv.dtype)  # (b, l, K-1, c)
            new_cache = {"conv": new_conv, "ssm": final,
                         "conv_seq": conv_seq, "ssm_seq": ssm_seq}
        else:
            y, final = ssd_chunked(
                xs, dt, A, B, C, _pick_chunk(l, cfg.ssm_chunk),
                init_state=cache["ssm"].astype(jnp.float32))
            new_cache = {"conv": new_conv, "ssm": final}
    else:
        # O(1) recurrent update (l == 1)
        s = cache["ssm"].astype(jnp.float32)                   # (b, h, p, n)
        dt1 = dt[:, 0]                                         # (b, h)
        dA = jnp.exp(dt1 * A[None, :])                         # (b, h)
        xd = xs[:, 0].astype(jnp.float32) * dt1[..., None]     # (b, h, p)
        s = s * dA[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xd, B[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", C[:, 0].astype(jnp.float32), s)
        y = y[:, None]                                         # (b, 1, h, p)
        new_cache = {"conv": new_conv, "ssm": s}

    y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(b, l, di).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = L.rmsnorm(params["norm"], y, cfg.norm_eps)
    out = apply_linear(y, params["out_proj"], sparsity)
    return out, new_cache
