"""Decoder-only LM assembly: one scan-over-layers body for every assigned
dense / MoE / VLM architecture (qwen3, gemma2/3, stablelm, qwen2-moe, dbrx,
qwen2-vl; zamba2/mamba2 live in hybrid.py/ssm assembly, seamless in
encdec.py).

Parameters are *stacked over layers* (every leaf gains a leading
``n_layers`` axis) so the layer loop is a single ``lax.scan`` — compile
time stays O(1) in depth, which keeps the 80-layer dry-run cells fast.
Heterogeneous local/global attention (gemma 5:1) is a per-layer scanned
int flag selecting the window mask at run time; MoE-vs-dense MLP is
homogeneous per arch and resolved statically.

The one entry point is :func:`lm_apply`:

  * training / no-cache forward:  ``lm_apply(p, cfg, tokens)`` → logits
  * prefill: pass a fresh ``init_kv_cache`` and ``cache_pos=0``
  * decode:  pass the running cache and the current position

Sparsity (the paper's technique) applies per layer family through
``cfg.attn_sparsity`` / ``cfg.mlp_sparsity`` / ``cfg.expert_sparsity`` —
projections dispatch through ``apply_linear`` which routes packed weights
to the sparse kernels.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models.attention import attention, init_attention
from repro.models.config import LayerKind, ModelConfig

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_layer(rng: Array, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    p: Params = {
        "ln_attn": L.init_rmsnorm(cfg.d_model),
        "ln_mlp": L.init_rmsnorm(cfg.d_model),
        "attn": init_attention(ks[0], cfg, dtype=dtype),
    }
    if cfg.post_norm:
        p["ln_attn_post"] = L.init_rmsnorm(cfg.d_model)
        p["ln_mlp_post"] = L.init_rmsnorm(cfg.d_model)
    if cfg.n_experts:
        p["moe"] = M.init_moe(ks[1], cfg, dtype=dtype)
    else:
        p["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                              gated=cfg.mlp_gated, dtype=dtype)
    return p


def init_lm(rng: Array, cfg: ModelConfig) -> Params:
    """Stacked-layer LM params (embed / layers / final norm)."""
    dtype = L._dtype(cfg.dtype)
    k_embed, k_layers, k_unembed = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    p: Params = {
        "embed": L.init_embedding(k_embed, cfg.vocab_padded, cfg.d_model,
                                  dtype),
        "layers": layers,
        "ln_final": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.init_embedding(k_unembed, cfg.vocab_padded,
                                        cfg.d_model, dtype)
    return p


# ---------------------------------------------------------------------------
# One transformer block (the scanned body)
# ---------------------------------------------------------------------------

def block(p: Params, cfg: ModelConfig, x: Array, positions: Array,
          kind: Array, cache: Optional[Params], cache_pos
          ) -> Tuple[Array, Optional[Params], Array]:
    """Pre-norm block: x + attn(norm(x)); x + mlp(norm(x)).

    ``kind`` is a traced int32 (LayerKind); returns (x, new_cache, aux).

    The residual stream is sequence-sharded over the model axis
    (Megatron-SP): the per-layer remat stack shrinks |model|×, and the
    TP boundary collectives become bf16 all-gather / reduce-scatter
    pairs instead of f32 all-reduces.  ``constrain`` is a no-op off-mesh
    and on shapes that don't divide (decode's Lq=1).
    """
    from repro.distributed.annotate import batch_axes, constrain, seq_axis
    x = constrain(x, batch_axes(), seq_axis(), None)
    is_local = kind == int(LayerKind.ATTN_LOCAL)
    h = L.rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    attn_out, new_cache = attention(
        p["attn"], cfg, h, positions, is_local=is_local,
        cache=cache, cache_pos=cache_pos, sparsity=cfg.attn_sparsity)
    # constrain sub-block outputs back to the SP layout while still bf16:
    # the row-parallel partial sums then reduce-scatter in bf16 instead of
    # all-reducing the f32 upcast the residual add would otherwise hoist
    attn_out = constrain(attn_out, batch_axes(), seq_axis(), None)
    if cfg.post_norm:
        attn_out = L.rmsnorm(p["ln_attn_post"], attn_out, cfg.norm_eps)
    x = x + attn_out

    h = L.rmsnorm(p["ln_mlp"], x, cfg.norm_eps)
    if cfg.n_experts:
        if cfg.moe_impl == "grouped":
            mlp_out, aux = M.moe_grouped(
                p["moe"], cfg, h, sparsity=cfg.expert_sparsity,
                capacity_factor=cfg.capacity_factor,
                group_size=cfg.moe_group)
        elif cfg.moe_impl == "sorted":
            mlp_out, aux = M.moe_sorted(
                p["moe"], cfg, h, sparsity=cfg.expert_sparsity,
                capacity_factor=cfg.capacity_factor,
                group_size=cfg.moe_group)
        else:
            mlp_out, aux = M.moe(p["moe"], cfg, h,
                                 sparsity=cfg.expert_sparsity)
    else:
        mlp_out = L.mlp(p["mlp"], h, gated=cfg.mlp_gated,
                        sparsity=cfg.mlp_sparsity)
        aux = jnp.zeros((), jnp.float32)
    mlp_out = constrain(mlp_out, batch_axes(), seq_axis(), None)
    if cfg.post_norm:
        mlp_out = L.rmsnorm(p["ln_mlp_post"], mlp_out, cfg.norm_eps)
    return x + mlp_out, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model apply
# ---------------------------------------------------------------------------

def lm_hidden(params: Params, cfg: ModelConfig, inputs: Array,
              positions: Optional[Array] = None,
              cache: Optional[Params] = None,
              cache_pos=None) -> Tuple[Array, Optional[Params], Array]:
    """Inputs → final (normed) hidden states — the shared trunk of
    ``lm_apply`` (logits) and ``lm_loss`` (chunked CE, no logits tensor).

    ``inputs``: (B, L) int tokens, or (B, L, d) float embeds when
    ``cfg.input_mode == 'embeds'`` (audio/VLM frontend stubs).
    ``positions``: (B, L) int32, or (B, L, 3) for M-RoPE; defaults to
    ``cache_pos + arange(L)``.
    ``cache``: stacked (n_layers, B, S, Hk, D) k/v dict from
    ``init_kv_cache``; ``cache_pos`` the scalar write offset.
    """
    if cfg.input_mode == "embeds" and inputs.ndim == 3:
        x = inputs.astype(L._dtype(cfg.dtype))
        B, Lq = inputs.shape[:2]
    else:
        B, Lq = inputs.shape
        x = L.embed(params["embed"], inputs, scale=cfg.embed_scale)
    if positions is None:
        base = jnp.arange(Lq)
        if cache_pos is not None:
            cp = jnp.asarray(cache_pos)
            # scalar offset (shared) or (B,) per-slot decode positions
            base = base[None, :] + (cp[:, None] if cp.ndim == 1 else cp)
        positions = jnp.broadcast_to(base, (B, Lq))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[..., None], (B, Lq, 3))

    kinds = jnp.asarray(cfg.layer_kinds, jnp.int32)

    def body(carry, xs):
        x, aux = carry
        p_layer, kind, cache_layer = xs
        x, new_cache, aux_l = block(p_layer, cfg, x, positions, kind,
                                    cache_layer, cache_pos)
        return (x, aux + aux_l), new_cache

    body_fn = body
    if cfg.remat and cache is None:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body_fn = jax.checkpoint(body, policy=policy)
    (x, aux), new_cache = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], kinds, cache))

    x = L.rmsnorm(params["ln_final"], x, cfg.norm_eps)
    return x, new_cache, aux


def lm_apply(params: Params, cfg: ModelConfig, inputs: Array,
             positions: Optional[Array] = None,
             cache: Optional[Params] = None,
             cache_pos=None, last_only: bool = False
             ) -> Tuple[Array, Optional[Params], Array]:
    """Inputs → logits f32 (B, L|1, vocab_padded).

    ``last_only`` unembeds just the final position — the prefill path,
    where materializing (B, 32768, V) logits would be hundreds of GB.
    """
    x, new_cache, aux = lm_hidden(params, cfg, inputs, positions, cache,
                                  cache_pos)
    if last_only:
        x = x[:, -1:]
    table = params.get("unembed", params["embed"])
    logits = L.unembed(table, x, softcap=cfg.final_softcap)
    return logits, new_cache, aux


def lm_logits(params: Params, cfg: ModelConfig, inputs: Array,
              positions: Optional[Array] = None) -> Array:
    """Training-mode forward (no cache)."""
    logits, _, _ = lm_apply(params, cfg, inputs, positions)
    return logits


# ---------------------------------------------------------------------------
# Loss (shared by trainers) — vocab-chunked cross-entropy
# ---------------------------------------------------------------------------

def chunked_ce(x: Array, table: Array, labels: Array, cfg: ModelConfig,
               chunk: int = 16384) -> Array:
    """Mean next-token CE from hidden states without a (B, L, V) tensor.

    Streams the unembedding in vocab chunks with an online logsumexp —
    the classic memory-efficient CE: peak extra memory is (B, L, chunk)
    instead of (B, L, V) (a 10–30× cut at 150k–260k vocabs; what lets the
    train_4k cells fit).  Exactly equals log_softmax+gather (tested).
    """
    from repro.distributed.annotate import batch_axes, constrain, seq_axis

    BATCH = batch_axes()
    MODEL = seq_axis()          # vocab chunks shard over model iff TP mode

    B, Lq, d = x.shape
    V = table.shape[0]
    if V % chunk:
        # pick the divisor of V closest below the target chunk (every
        # vocab_padded is a multiple of 512, so a good divisor exists)
        nc_min = max(1, -(-V // chunk))          # ceil
        chunk = next((V // nc for nc in range(nc_min, V + 1) if V % nc == 0),
                     V)
    nc = V // chunk
    tc = table.reshape(nc, chunk, d)
    # vocab-parallel CE (Megatron-style): each model shard scores its own
    # vocab slice; the only collectives are (B, L)-sized logsumexp psums
    tc = constrain(tc, None, MODEL, None)
    x32 = constrain(x.astype(jnp.float32), BATCH, None, None)

    def step(carry, xs):
        m_run, l_run, lab_logit = carry
        tb, ci = xs
        lo = ci * chunk
        s = jnp.einsum("bld,vd->blv", x32, tb.astype(jnp.float32))
        s = constrain(s, BATCH, None, MODEL)
        if cfg.final_softcap is not None:
            s = jnp.tanh(s / cfg.final_softcap) * cfg.final_softcap
        # mask vocab padding inside the chunk
        valid = (lo + jnp.arange(chunk)) < cfg.vocab_size
        s = jnp.where(valid[None, None, :], s, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        l_new = l_run * jnp.exp(m_run - m_new) + jnp.sum(
            jnp.exp(s - m_new[..., None]), axis=-1)
        # label logit if the label falls in this chunk
        in_chunk = (labels >= lo) & (labels < lo + chunk)
        idx = jnp.clip(labels - lo, 0, chunk - 1)
        got = jnp.take_along_axis(s, idx[..., None], axis=-1)[..., 0]
        lab_logit = jnp.where(in_chunk, got, lab_logit)
        return (m_new, l_new, lab_logit), None

    init = (jnp.full((B, Lq), -1e30, jnp.float32),
            jnp.zeros((B, Lq), jnp.float32),
            jnp.full((B, Lq), -1e30, jnp.float32))
    # checkpoint the chunk step: without it backward saves every chunk's
    # (B, L, chunk) logits — at 150k+ vocabs that is the single biggest
    # training buffer (≫ all activations combined)
    (m, l, lab), _ = jax.lax.scan(jax.checkpoint(step), init,
                                  (tc, jnp.arange(nc)))
    nll = (m + jnp.log(l)) - lab
    return jnp.mean(nll)


def lm_loss(params: Params, cfg: ModelConfig, tokens: Array, labels: Array,
            aux_weight: float = 0.01) -> Array:
    """Mean next-token cross-entropy (+ MoE aux), vocab padding masked."""
    x, _, aux = lm_hidden(params, cfg, tokens)
    table = params.get("unembed", params["embed"])
    return chunked_ce(x, table, labels, cfg) + aux_weight * aux


def mask_vocab_padding(logits: Array, cfg: ModelConfig) -> Array:
    if cfg.vocab_padded == cfg.vocab_size:
        return logits
    pad = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
    return jnp.where(pad, -1e30, logits)


# ---------------------------------------------------------------------------
# Serving steps (prefill / decode) — lowered by the dry-run's serve cells
# ---------------------------------------------------------------------------

def lm_prefill(params: Params, cfg: ModelConfig, inputs: Array,
               cache: Params) -> Tuple[Array, Params]:
    """Fill the cache with the prompt; return last-position logits."""
    logits, new_cache, _ = lm_apply(params, cfg, inputs, cache=cache,
                                    cache_pos=jnp.zeros((), jnp.int32),
                                    last_only=True)
    return logits[:, -1], new_cache


def lm_decode_step(params: Params, cfg: ModelConfig, token: Array,
                   cache: Params, pos: Array) -> Tuple[Array, Params]:
    """One decode step: ``token (B,)`` + cache at ``pos`` → next logits."""
    logits, new_cache, _ = lm_apply(params, cfg, token[:, None],
                                    cache=cache, cache_pos=pos)
    return logits[:, 0], new_cache


def lm_decode_block(params: Params, cfg: ModelConfig, tokens: Array,
                    cache: Params, pos: Array) -> Tuple[Array, Params]:
    """Multi-token decode-shaped forward (the speculative verify step):
    ``tokens (B, T)`` written at per-slot positions ``pos (B,)``, causal
    within the block — one batched forward instead of T decode steps.
    Returns logits for every block position ``(B, T, vocab_padded)``."""
    logits, new_cache, _ = lm_apply(params, cfg, tokens,
                                    cache=cache, cache_pos=pos)
    return logits, new_cache
