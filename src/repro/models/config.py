"""Unified model configuration covering every assigned architecture family.

One dataclass describes dense, MoE, SSM, hybrid, enc-dec and VLM/audio
backbones; per-family fields are simply unused elsewhere.  Configs are
plain frozen dataclasses so they hash (static args of jitted steps) and
print reproducibly into EXPERIMENTS.md.

Layer heterogeneity (gemma local:global patterns, zamba2 mamba:attn
interleave) is expressed as ``layer_kinds`` — a per-layer tuple of
:class:`LayerKind` — so a single ``lax.scan`` with per-layer scalar flags
runs every family (compile time stays O(1) in depth, which is what lets
the 80-layer dry-run cells compile quickly).
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Tuple

from repro.core.sparse_linear import SparsityConfig, DENSE


class LayerKind(enum.IntEnum):
    """What sequence mixer a layer uses (scanned as an int32 flag)."""
    ATTN_GLOBAL = 0      # full causal attention
    ATTN_LOCAL = 1       # sliding-window attention
    MAMBA = 2            # Mamba-2 SSD block
    SHARED_ATTN = 3      # zamba2: the *shared* attention block is applied
                         # before this (mamba) layer


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    vocab_size: int

    # --- attention ---
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                    # 0 → d_model // n_heads
    qk_norm: bool = False                # qwen3
    attn_softcap: Optional[float] = None  # gemma2 (50.0)
    final_softcap: Optional[float] = None  # gemma2 (30.0)
    window_size: Optional[int] = None    # sliding window for local layers
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # --- mlp ---
    d_ff: int = 0
    mlp_gated: bool = True               # SwiGLU/GeGLU vs plain

    # --- layer pattern ---
    layer_kinds: Tuple[int, ...] = ()    # defaults to all ATTN_GLOBAL

    # --- MoE ---
    n_experts: int = 0                   # 0 → dense MLP
    n_shared_experts: int = 0            # qwen2-moe: always-on experts
    top_k: int = 0
    d_expert: int = 0                    # 0 → d_ff
    moe_sharding: str = "ep"             # "ep" (experts over model axis) |
                                         # "tp" (expert-internal over model)
    moe_impl: str = "grouped"            # "dense" (all-experts baseline) |
                                         # "grouped" (GShard capacity dispatch)
    capacity_factor: float = 1.25
    moe_group: int = 4096                # GShard token-group size S

    # --- SSM (mamba2) ---
    ssm_state: int = 0                   # N (state dim per head)
    ssm_heads: int = 0                   # H; 0 → d_inner // ssm_head_dim
    ssm_head_dim: int = 64               # P
    ssm_expand: int = 2                  # d_inner = expand * d_model
    ssm_groups: int = 1                  # B/C groups (G)
    ssm_conv: int = 4                    # short conv window
    ssm_chunk: int = 256                 # SSD chunk length

    # --- enc-dec (seamless) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- modality frontend stub ---
    input_mode: str = "tokens"           # "tokens" | "embeds" (audio/vlm stub)

    # --- norms / embeddings ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False            # gemma multiplies embeds by sqrt(d)
    post_norm: bool = False              # gemma2/3: extra norm after mixer/mlp

    # --- sparsity (the paper's technique, per layer family) ---
    mlp_sparsity: SparsityConfig = DENSE
    attn_sparsity: SparsityConfig = DENSE
    expert_sparsity: SparsityConfig = DENSE

    # --- numerics / distribution ---
    dtype: str = "bfloat16"
    remat: bool = True                   # checkpoint each scanned layer
    remat_policy: str = "full"           # "full" | "dots" (save matmul
                                         # outputs, recompute elementwise)
    scan_layers: bool = True

    def __post_init__(self):
        if not self.layer_kinds:
            object.__setattr__(
                self, "layer_kinds",
                tuple([int(LayerKind.ATTN_GLOBAL)] * self.n_layers))
        if len(self.layer_kinds) != self.n_layers:
            raise ValueError(
                f"layer_kinds has {len(self.layer_kinds)} entries for "
                f"{self.n_layers} layers")
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and not self.d_expert:
            object.__setattr__(self, "d_expert", self.d_ff)

    # ---- derived quantities --------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or self.d_inner // self.ssm_head_dim

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a 512 multiple so ("model" TP × 128-lane)
        sharding always divides (e.g. seamless's 256206 → 256512)."""
        return math.ceil(self.vocab_size / 512) * 512

    @property
    def uses_mamba(self) -> bool:
        return any(k in (LayerKind.MAMBA, LayerKind.SHARED_ATTN)
                   for k in self.layer_kinds)

    @property
    def uses_attention(self) -> bool:
        return any(k in (LayerKind.ATTN_GLOBAL, LayerKind.ATTN_LOCAL,
                         LayerKind.SHARED_ATTN)
                   for k in self.layer_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """True when decoding at 500k context is feasible: every attention
        layer is windowed or the model is (mostly) attention-free."""
        kinds = [LayerKind(k) for k in self.layer_kinds]
        n_global = sum(k == LayerKind.ATTN_GLOBAL for k in kinds)
        n_total = len(kinds)
        # mamba/hybrid: fine. few-global (gemma3 5:1): KV for global layers
        # is O(L) but there are few of them and batch=1 — allowed.
        return n_global <= max(1, n_total // 5)

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, V = self.d_model, self.vocab_size
        n = V * d  # embed
        if not self.tie_embeddings:
            n += V * d
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ff = self.d_ff
        per_mlp = d * ff * (3 if self.mlp_gated else 2)
        per_expert = d * self.d_expert * (3 if self.mlp_gated else 2)
        per_moe = self.n_experts * per_expert + d * self.n_experts \
            + self.n_shared_experts * per_expert
        if self.uses_mamba:
            di, N, H = self.d_inner, self.ssm_state, self.n_ssm_heads
            G = self.ssm_groups
            per_mamba = d * (2 * di + 2 * G * N + H) + di * d \
                + self.ssm_conv * (di + 2 * G * N)
        kinds = [LayerKind(k) for k in self.layer_kinds]
        for k in kinds:
            if k in (LayerKind.ATTN_GLOBAL, LayerKind.ATTN_LOCAL):
                n += per_attn + (per_moe if self.n_experts else per_mlp)
            elif k == LayerKind.MAMBA:
                n += per_mamba
            elif k == LayerKind.SHARED_ATTN:
                n += per_mamba  # the shared attn params are counted once:
        if LayerKind.SHARED_ATTN in kinds:
            n += per_attn + per_mlp
        if self.is_encoder_decoder:
            # encoder self-attn + mlp, decoder cross-attn
            n += self.n_encoder_layers * (per_attn + per_mlp)
            n += self.n_layers * per_attn          # cross-attention
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        per_expert = d * self.d_expert * (3 if self.mlp_gated else 2)
        inactive = (self.n_experts - self.top_k) * per_expert
        n_moe_layers = sum(
            1 for k in self.layer_kinds
            if LayerKind(k) in (LayerKind.ATTN_GLOBAL, LayerKind.ATTN_LOCAL))
        return self.param_count() - n_moe_layers * inactive


def interleave_kinds(n_layers: int, local: int, global_: int,
                     window_first: bool = True) -> Tuple[int, ...]:
    """gemma-style ``local:global`` repeating pattern (e.g. 5:1)."""
    pat = ([int(LayerKind.ATTN_LOCAL)] * local
           + [int(LayerKind.ATTN_GLOBAL)] * global_)
    if not window_first:
        pat = pat[::-1]
    out = (pat * math.ceil(n_layers / len(pat)))[:n_layers]
    return tuple(out)


def zamba_kinds(n_layers: int, shared_every: int = 6) -> Tuple[int, ...]:
    """zamba2: mamba backbone with the shared attention block applied
    every ``shared_every`` layers (starting at the first slot)."""
    out = []
    for i in range(n_layers):
        if i % shared_every == shared_every // 2:
            out.append(int(LayerKind.SHARED_ATTN))
        else:
            out.append(int(LayerKind.MAMBA))
    return tuple(out)
